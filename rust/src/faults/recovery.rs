//! Crash and lifecycle orchestration, plus NameNode-driven block
//! re-replication.
//!
//! A crash is handled in four strictly ordered steps, all inside one
//! engine batch (a single simulated instant, one rate solve):
//!
//! 1. mark the node dead (fault state + NameNode blacklist), so every
//!    subsequent placement / replica pick avoids it;
//! 2. run the registered protocol failover handlers (in-flight HDFS
//!    writes rebuild their pipeline over the survivors, reads re-point
//!    at a surviving replica, the job scheduler blacklists the
//!    TaskTracker and re-queues its work);
//! 3. cancel every remaining flow touching the dead node's resources —
//!    the kill-switch for work no handler claimed (tasks running *on*
//!    the node, shuffle fetches served by it);
//! 4. start re-replication transfers for every block that lost a
//!    replica, sourced from the first surviving copy (deterministic) to
//!    a live non-holder target.
//!
//! The **node lifecycle** handlers live here too:
//!
//! * [`handle_decommission`] — graceful exit: the node stops receiving
//!   new replicas and tasks, *drains* every block that would fall under
//!   the replication factor (sourced from itself), and goes
//!   administratively dead when the drain lands. No flows are
//!   cancelled; running attempts finish.
//! * [`handle_recommission`] — a dead node re-joins: resources re-arm
//!   to nominal, a dark ToR uplink is repaired, the **block report**
//!   replays (intact copies re-register, redundant ones are
//!   invalidated), remaining under-replicated blocks repair onto the
//!   returning capacity, and the TaskTracker re-registers with the
//!   JobTracker. Recommissioning a still-live decommissioning node
//!   cancels the drain instead.
//!
//! Recovery transfers carry `recovery:*` usage classes so the energy
//! layer can attribute their joules separately
//! ([`crate::energy::EnergyReport::recovery_joules`]); balancer moves
//! ride the same transfer path under `balance:*`
//! ([`crate::energy::EnergyReport::balance_joules`]).
//!
//! Simplification: a transfer whose source or target dies mid-copy is
//! cancelled by that crash's kill-switch; the next scan retries from
//! the survivors (the one leaked disk-stream count on the surviving
//! endpoint only matters for the HDD seek model and only after a
//! double crash, and is cleared if the node ever re-joins).

use std::collections::BTreeMap;

use crate::cluster::NodeId;
use crate::hdfs::{ReplTask, WorldHandle};
use crate::sim::{Engine, FlowSpec};

use super::{balancer, dispatch_crash, dispatch_drain, dispatch_rejoin};

/// Process a node-crash fault event end to end. Idempotent: a second
/// crash of the same node is a no-op.
pub fn handle_crash(engine: &mut Engine, world: &WorldHandle, node: NodeId) {
    let stalled_drains = {
        let mut w = world.borrow_mut();
        if !w.faults.set_down(node) {
            return;
        }
        w.faults.stats.crashes += 1;
        w.faults.mark_hard(node);
        w.namenode.mark_dead(node);
        // In-flight balancer moves and drain copies touching the node
        // die with its flows; forget them so later rounds can re-plan
        // those blocks, and restart any drain whose copy just died
        // with its target.
        w.faults.purge_pending_for_dead(&[node])
    };
    if engine.trace_enabled() {
        engine.trace_instant("faults", format!("crash n{}", node.0), node.0 as u32);
    }
    if engine.metrics_enabled() {
        engine.metric_incr("faults.crashes", 1);
    }
    // A crash mid-drain ends the drain; close its lifecycle span.
    end_drain_span(engine, world, node);
    let world2 = world.clone();
    engine.batch(move |engine| {
        dispatch_crash(engine, &world2, node);
        let resources = {
            let w = world2.borrow();
            w.cluster.node_resources(node)
        };
        for r in resources {
            engine.cancel_flows_on(r);
        }
        start_rereplication(engine, &world2, &[node]);
    });
    restart_stalled_drains(engine, world, stalled_drains);
    // The namespace just re-skewed; wake a parked balancer.
    balancer::kick(engine, world);
}

/// Restart drain loops whose in-flight copy died with a crashed
/// endpoint (deduplicated; draining nodes that meanwhile died or
/// cancelled are skipped by `drain_round`'s own guard).
fn restart_stalled_drains(engine: &mut Engine, world: &WorldHandle, mut stalled: Vec<NodeId>) {
    stalled.sort_unstable();
    stalled.dedup();
    for s in stalled {
        drain_round(engine, world, s);
    }
}

/// Process a straggler fault event: the node's CPU drops to `factor`
/// of nominal capacity (dead nodes are skipped).
pub fn handle_straggle(engine: &mut Engine, world: &WorldHandle, node: NodeId, factor: f64) {
    let cpu = {
        let mut w = world.borrow_mut();
        if !w.faults.is_up(node) {
            return;
        }
        w.faults.stats.stragglers += 1;
        w.cluster.node(node).cpu
    };
    if engine.trace_enabled() {
        engine.trace_instant(
            "faults",
            format!("straggler n{} cpu x{:.2}", node.0, factor.clamp(0.01, 1.0)),
            node.0 as u32,
        );
    }
    let cap = engine.resource(cpu).capacity;
    engine.set_capacity(cpu, cap * factor.clamp(0.01, 1.0));
}

/// Process a disk-degrade fault event (dead nodes are skipped).
pub fn handle_disk_degrade(engine: &mut Engine, world: &WorldHandle, node: NodeId, factor: f64) {
    let mut w = world.borrow_mut();
    if !w.faults.is_up(node) {
        return;
    }
    w.faults.stats.disk_degrades += 1;
    let f = factor.clamp(0.01, 1.0);
    w.cluster.set_disk_degrade(engine, node, f);
}

/// Process a whole-rack failure: every node in `rack` dies at once
/// together with the rack's ToR uplink. The master (node 0) is spared —
/// a master failure is a whole-job failure, out of scope for this model
/// — and the last live DataNode is never killed (a dead cluster can
/// neither place replicas nor finish a job).
///
/// Unlike a sequence of single crashes, the whole dead set is marked
/// *before* any failover handler runs, so pipeline rebuilds, replica
/// picks and re-replication targets already avoid the entire failure
/// domain — which is exactly why rack-aware placement keeps every block
/// recoverable, and why all the repair traffic crosses the (possibly
/// oversubscribed) fabric.
pub fn handle_rack_crash(engine: &mut Engine, world: &WorldHandle, rack: usize) {
    let members: Vec<NodeId> = {
        let w = world.borrow();
        // Rack faults are meaningless on the flat single-rack topology
        // (rack 0 would be the entire cluster) and on unknown indices.
        if w.cluster.racks() <= 1 || rack >= w.cluster.racks() {
            return;
        }
        w.cluster.rack_nodes(rack).into_iter().filter(|n| n.0 != 0).collect()
    };
    let mut newly_dead: Vec<NodeId> = Vec::new();
    let stalled_drains = {
        let mut w = world.borrow_mut();
        w.faults.stats.rack_crashes += 1;
        for &n in &members {
            if !w.faults.is_up(n) {
                continue;
            }
            // Keep the last placement-eligible DataNode alive: counting
            // merely-live nodes here would let the crash spare only a
            // *draining* node, whose own drain completion would then
            // leave the cluster with zero placement targets.
            if w.namenode.is_datanode(n) && w.namenode.target_datanodes().len() <= 1 {
                continue;
            }
            let _ = w.faults.set_down(n);
            w.faults.mark_hard(n);
            w.namenode.mark_dead(n);
            w.faults.stats.crashes += 1;
            newly_dead.push(n);
        }
        w.faults.purge_pending_for_dead(&newly_dead)
    };
    if engine.trace_enabled() {
        engine.trace_instant(
            "faults",
            format!("rack {rack} crash ({} nodes down)", newly_dead.len()),
            0,
        );
    }
    if engine.metrics_enabled() {
        engine.metric_incr("faults.rack_crashes", 1);
    }
    // A member can be spared (already dead, or the last live DataNode).
    // Only when the rack is genuinely empty of live nodes does its ToR
    // go dark — draining the uplink under a live spared member would
    // cancel its in-flight cross-rack flows with no failover dispatched
    // for it, silently stranding those protocol chains.
    let all_members_down = {
        let w = world.borrow();
        !members.is_empty() && members.iter().all(|&n| !w.faults.is_up(n))
    };
    let world2 = world.clone();
    engine.batch(move |engine| {
        // The ToR uplink goes dark: drain in-flight cross-rack flows and
        // floor the capacity. With every member dead nothing can start a
        // new flow across it; the 1% floor merely keeps rate solving
        // well-conditioned if one ever did.
        let uplink = {
            let w = world2.borrow();
            w.cluster.rack_uplink(rack).map(|u| (u.up, u.down))
        };
        if let Some((up, down)) = uplink.filter(|_| all_members_down) {
            engine.cancel_flows_on(up);
            engine.cancel_flows_on(down);
            let mut w = world2.borrow_mut();
            w.cluster.set_uplink_degrade(engine, rack, 0.01);
            w.cluster.set_uplink_dark(rack, true);
        }
        // Protocol failovers plus the flow kill-switch, per dead node.
        for &n in &newly_dead {
            dispatch_crash(engine, &world2, n);
            let resources = {
                let w = world2.borrow();
                w.cluster.node_resources(n)
            };
            for r in resources {
                engine.cancel_flows_on(r);
            }
        }
        // Re-replicate everything the rack held in one scan (so two
        // same-instant repairs of one block pick distinct targets);
        // targets already exclude the whole rack, so every transfer
        // crosses the fabric.
        start_rereplication(engine, &world2, &newly_dead);
    });
    restart_stalled_drains(engine, world, stalled_drains);
    balancer::kick(engine, world);
}

/// Process a ToR-uplink brownout: the rack's uplink capacity dips to
/// `factor` of nominal in both directions (in-flight cross-rack flows
/// simply re-solve at the new rate). Brownouts only ever *lower*
/// capacity — a dip arriving after a whole-rack crash (or a deeper
/// earlier brownout) must not revive the floored uplink. Flat
/// topologies and unknown rack indices are no-ops.
pub fn handle_rack_brownout(engine: &mut Engine, world: &WorldHandle, rack: usize, factor: f64) {
    let mut w = world.borrow_mut();
    let current = match w.cluster.rack_uplink(rack) {
        Some(u) => u.degrade,
        None => return,
    };
    w.faults.stats.rack_brownouts += 1;
    w.cluster.set_uplink_degrade(engine, rack, factor.clamp(0.01, 1.0).min(current));
    if engine.trace_enabled() {
        engine.trace_instant(
            "faults",
            format!("rack {rack} brownout x{:.2}", factor.clamp(0.01, 1.0).min(current)),
            0,
        );
    }
}

/// Process a graceful decommission: mark the node *decommissioning*
/// (placement and scheduling stop immediately; reads keep working),
/// drain every block that would fall below the replication factor once
/// the node leaves — sourced from the node itself — and declare the
/// node administratively dead when the last drain transfer lands.
/// Unlike a crash, nothing is cancelled: running task attempts and
/// in-flight reads complete normally.
pub fn handle_decommission(engine: &mut Engine, world: &WorldHandle, node: NodeId) {
    {
        let mut w = world.borrow_mut();
        if !w.faults.is_up(node)
            || !w.namenode.is_datanode(node)
            || w.namenode.is_decommissioning(node)
        {
            return;
        }
        // Never drain the last eligible target: its blocks would have
        // nowhere to go and the cluster would end with no DataNode.
        if w.namenode.target_datanodes().len() <= 1 {
            return;
        }
        w.faults.stats.decommissions += 1;
        w.namenode.mark_decommissioning(node);
    }
    if engine.trace_enabled() {
        engine.trace_instant("faults", format!("decommission n{}", node.0), node.0 as u32);
    }
    // The drain is a *duration*: open a lifecycle span that closes when
    // the node goes dead, the decommission is cancelled, or the node
    // crashes mid-drain.
    if engine.spans_enabled() {
        let span = engine.span_begin("lifecycle", format!("drain n{}", node.0), node.0 as u32);
        world.borrow_mut().faults.drain_spans.push((node, span));
    }
    // The JobTracker stops assigning work to the draining tracker.
    dispatch_drain(engine, world, node);
    drain_round(engine, world, node);
}

/// One drain iteration: scan for blocks whose live replica count would
/// fall below the factor once `node` leaves (skipping blocks whose
/// drain copy is already in flight), copy each off the node, and
/// **re-scan** after every landed copy: a pipeline that was already
/// streaming toward the node when the drain started commits its block
/// afterwards, and that block must drain too. The node only goes dead
/// on a clean scan with nothing in flight (Hadoop's "decommission ends
/// when all blocks are sufficiently replicated elsewhere"). In-flight
/// copies are tracked in `FaultState::drain_pending`, so a crash that
/// cancels one (its completion callback never runs) is repaired by
/// [`handle_crash`], which purges the dead endpoint's entries and
/// restarts the stalled drain.
pub(crate) fn drain_round(engine: &mut Engine, world: &WorldHandle, node: NodeId) {
    let replication = {
        let w = world.borrow();
        // A crash or cancellation mid-drain ends the loop.
        if !w.faults.is_up(node) || !w.namenode.is_decommissioning(node) {
            return;
        }
        w.faults.replication
    };
    // Drain plan: one copy per block whose live replica count (without
    // this node) is short of the factor. Sorted file scan, deterministic.
    let (tasks, has_pending) = {
        let w = world.borrow();
        let pending: Vec<u64> = w
            .faults
            .drain_pending
            .iter()
            .filter(|p| p.source == node)
            .map(|p| p.block_id)
            .collect();
        // Borrowed names, sorted once; only blocks that actually need a
        // copy pay for a string clone (re-scans run per landed copy).
        let mut names: Vec<&str> = w.namenode.files().map(|(n, _)| n).collect();
        names.sort_unstable();
        let mut tasks = Vec::new();
        for name in names {
            let meta = w.namenode.get_file(name).expect("file vanished during drain scan");
            for (i, b) in meta.blocks.iter().enumerate() {
                if !b.replicas.contains(&node) || pending.contains(&b.id) {
                    continue;
                }
                let survivors = b
                    .replicas
                    .iter()
                    .filter(|r| **r != node && !w.namenode.is_dead(**r))
                    .count();
                if survivors >= replication {
                    continue;
                }
                tasks.push(ReplTask {
                    file: name.to_string(),
                    block_idx: i,
                    block_id: b.id,
                    bytes: b.stored_size,
                    source: node,
                    holders: b.replicas.clone(),
                });
            }
        }
        (tasks, !pending.is_empty())
    };
    if tasks.is_empty() {
        // Nothing left to copy: done when nothing is in flight either;
        // otherwise the in-flight completions re-scan.
        if !has_pending {
            finish_drain(engine, world, node);
        }
        return;
    }
    let world2 = world.clone();
    let started = engine.batch(|engine| {
        let mut planned: BTreeMap<u64, Vec<NodeId>> = BTreeMap::new();
        let mut started = 0usize;
        for t in &tasks {
            let block_id = t.block_id;
            let wfin = world2.clone();
            let target = plan_and_start(engine, &world2, t, &mut planned, move |engine, w| {
                w.faults
                    .drain_pending
                    .retain(|p| !(p.block_id == block_id && p.source == node));
                // The world is borrowed here; re-scan on a same-instant
                // timer instead.
                let wfin = wfin.clone();
                engine.after(0.0, move |e| drain_round(e, &wfin, node));
            });
            // No eligible target (tiny or half-dead cluster): the block
            // keeps its copy only until the node leaves.
            let Some(target) = target else { continue };
            started += 1;
            world2.borrow_mut().faults.drain_pending.push(super::PendingMove {
                block_id,
                source: node,
                target,
                bytes: t.bytes.max(1.0),
            });
        }
        started
    });
    if started == 0 && !has_pending {
        // Every task was target-less and nothing is in flight:
        // re-scanning would find the same dead end, so the drain
        // completes under-replicated.
        finish_drain(engine, world, node);
    }
}

/// Complete a drain: the decommissioning node goes administratively
/// dead — out of placement, reads, and the balancer — without touching
/// its in-flight flows. Skipped if the node crashed mid-drain (the
/// crash path already handled it) or the decommission was cancelled.
fn finish_drain(engine: &mut Engine, world: &WorldHandle, node: NodeId) {
    {
        let mut w = world.borrow_mut();
        if !w.faults.is_up(node) || !w.namenode.is_decommissioning(node) {
            return;
        }
        let _ = w.faults.set_down(node);
        w.namenode.mark_dead(node);
        // Strip the node's replicas (the purge also records the block
        // report a recommission replays). The returned repair tasks are
        // dropped on purpose: post-drain counts already satisfy the
        // factor wherever a target existed.
        let _ = w.namenode.purge_node(node);
        // A drain that ran out of targets (crashes killed them mid-way)
        // can empty a sole-replica block — count it lost like the crash
        // path does, instead of reporting clean data loss.
        let lost = w
            .namenode
            .files()
            .flat_map(|(_, f)| f.blocks.iter())
            .filter(|b| b.replicas.is_empty())
            .count();
        if lost > w.faults.stats.blocks_lost {
            w.faults.stats.blocks_lost = lost;
        }
    }
    if engine.trace_enabled() {
        engine.trace_instant("faults", format!("drain complete n{} (dead)", node.0), node.0 as u32);
    }
    end_drain_span(engine, world, node);
    balancer::kick(engine, world);
}

/// Close the open `"lifecycle"` drain span for `node`, if any. No-op
/// when span recording is off (no span was stored) or no drain is open.
fn end_drain_span(engine: &mut Engine, world: &WorldHandle, node: NodeId) {
    let span = {
        let mut w = world.borrow_mut();
        match w.faults.drain_spans.iter().position(|(n, _)| *n == node) {
            Some(i) => w.faults.drain_spans.swap_remove(i).1,
            None => return,
        }
    };
    engine.span_end(span);
}

/// Process a recommission: a dead node re-joins the cluster — or, if
/// the node is still alive and draining, the decommission is cancelled
/// (Hadoop's remove-from-excludes refresh). See the module docs for the
/// full re-join sequence.
pub fn handle_recommission(engine: &mut Engine, world: &WorldHandle, node: NodeId) {
    enum Action {
        Skip,
        CancelDrain,
        Rejoin,
    }
    let action = {
        let w = world.borrow();
        if w.faults.is_up(node) {
            if w.namenode.is_decommissioning(node) {
                Action::CancelDrain
            } else {
                Action::Skip
            }
        } else if !w.namenode.is_datanode(node) {
            Action::Skip
        } else {
            Action::Rejoin
        }
    };
    match action {
        Action::Skip => {}
        Action::CancelDrain => {
            {
                let mut w = world.borrow_mut();
                w.faults.stats.recommissions += 1;
                w.namenode.cancel_decommission(node);
                // Drain copies that already landed are surplus now that
                // the original holder is staying; shed them. (In-flight
                // copies self-cancel: their commit sees the block is no
                // longer short and refuses.)
                let cap = w.faults.replication;
                w.faults.stats.excess_replicas_dropped +=
                    w.namenode.scan_over_replicated(cap);
            }
            if engine.trace_enabled() {
                engine.trace_instant(
                    "faults",
                    format!("decommission cancelled n{}", node.0),
                    node.0 as u32,
                );
            }
            end_drain_span(engine, world, node);
            // The tracker never died; give it its slots back.
            dispatch_rejoin(engine, world, node);
            balancer::kick(engine, world);
        }
        Action::Rejoin => {
            let replication = {
                let mut w = world.borrow_mut();
                w.faults.stats.recommissions += 1;
                let _ = w.faults.set_up(node);
                let hard = w.faults.take_hard(node);
                // Fresh hardware: nominal CPU/NIC/bus/disk capacities;
                // crash-leaked stream counts reset.
                w.cluster.rearm_node(engine, node, hard);
                // The first member back repairs a dark ToR uplink.
                let rack = w.cluster.rack_of(node);
                if w.cluster.rack_uplink(rack).map(|u| u.dark).unwrap_or(false) {
                    w.cluster.restore_uplink(engine, rack);
                }
                // Block report: intact copies re-register where the
                // namespace is short; redundant ones are invalidated.
                let replication = w.faults.replication;
                let (restored, excess) = w.namenode.recommission(node, replication);
                w.faults.stats.blocks_restored_on_rejoin += restored;
                w.faults.stats.excess_replicas_dropped += excess;
                // Over-replication scan: repairs that landed while the
                // report was being replayed can overshoot the factor.
                w.faults.stats.excess_replicas_dropped +=
                    w.namenode.scan_over_replicated(replication);
                replication
            };
            // Under-replication scan: blocks that could not repair while
            // the cluster was short of targets can now use the returning
            // capacity (this is also what resurrects a lost block whose
            // only copy came back with the node). Blocks with a drain or
            // balancer copy already in flight are skipped — the landing
            // commit would refuse the duplicate anyway, but not before a
            // full block of wire traffic was wasted and counted.
            let tasks = {
                let w = world.borrow();
                let mut tasks = w.namenode.scan_under_replicated(replication);
                tasks.retain(|t| {
                    !w.faults.drain_pending.iter().any(|p| p.block_id == t.block_id)
                        && !w.faults.balancer_pending.iter().any(|p| p.block_id == t.block_id)
                });
                tasks
            };
            if engine.trace_enabled() {
                engine.trace_instant(
                    "faults",
                    format!("recommission n{} ({} repairs)", node.0, tasks.len()),
                    node.0 as u32,
                );
            }
            // The re-join itself is instantaneous in the model; record
            // it as a zero-duration lifecycle span so span-graph
            // consumers see the transition alongside the drains.
            if engine.spans_enabled() {
                let span = engine.span_begin(
                    "lifecycle",
                    format!("rejoin n{} ({} repairs)", node.0, tasks.len()),
                    node.0 as u32,
                );
                engine.span_end(span);
            }
            if engine.metrics_enabled() {
                engine.metric_incr("faults.recommissions", 1);
            }
            if !tasks.is_empty() {
                let world2 = world.clone();
                engine.batch(move |engine| {
                    start_repl_tasks(engine, &world2, tasks);
                });
            }
            // TaskTracker re-registration with every live job.
            dispatch_rejoin(engine, world, node);
            // The empty node is the balancer's next target.
            balancer::kick(engine, world);
        }
    }
}

/// Process a whole-rack recommission: every dead member re-joins (the
/// ToR uplink is repaired by the first one). Flat topologies and
/// unknown rack indices are no-ops.
pub fn handle_rack_recommission(engine: &mut Engine, world: &WorldHandle, rack: usize) {
    let members: Vec<NodeId> = {
        let w = world.borrow();
        if w.cluster.racks() <= 1 || rack >= w.cluster.racks() {
            return;
        }
        w.cluster.rack_nodes(rack).into_iter().filter(|n| n.0 != 0).collect()
    };
    for n in members {
        let down = !world.borrow().faults.is_up(n);
        if down {
            handle_recommission(engine, world, n);
        }
    }
}

/// Scan the namespace for blocks that lost a replica on any of `dead`
/// and start one transfer per recoverable lost copy; blocks whose last
/// replica died are counted lost. All the dead nodes of one failure
/// instant must come through a **single** call: a block that lost two
/// replicas at once (whole-rack crash) spawns two same-instant repairs,
/// and the second must exclude the first's in-flight target —
/// `add_replica` dedupes, so a collision would leave the block
/// permanently under-replicated while the stats counted two repairs.
fn start_rereplication(engine: &mut Engine, world: &WorldHandle, dead: &[NodeId]) {
    let tasks = {
        let mut w = world.borrow_mut();
        let mut tasks = Vec::new();
        for &d in dead {
            tasks.extend(w.namenode.purge_node(d));
        }
        tasks
    };
    start_repl_tasks(engine, world, tasks);
    let mut w = world.borrow_mut();
    let lost = w
        .namenode
        .files()
        .flat_map(|(_, f)| f.blocks.iter())
        .filter(|b| b.replicas.is_empty())
        .count();
    if lost > w.faults.stats.blocks_lost {
        w.faults.stats.blocks_lost = lost;
    }
}

/// Commit one landed repair/drain copy: register `target` as a replica
/// of the block — unless the target died mid-copy (a dead target is
/// retried by the next scan) or the block meanwhile reached the
/// replication factor without it (a recommissioned holder's block
/// report can race an in-flight repair; committing anyway would leave
/// the block permanently over-replicated, since no later scan runs).
/// "Reached the factor" counts only *effective* copies — live and not
/// draining — so a drain copy still commits while the departing node's
/// own replica pads the raw list. Returns whether the replica was
/// registered.
fn commit_replica(
    w: &mut crate::hdfs::World,
    file: &str,
    block_idx: usize,
    target: NodeId,
) -> bool {
    if !w.faults.is_up(target) {
        return false;
    }
    let cap = w.faults.replication;
    let short = match w.namenode.get_file(file).and_then(|m| m.blocks.get(block_idx)) {
        Some(b) => {
            let effective = b
                .replicas
                .iter()
                .filter(|r| {
                    w.namenode.is_live(**r) && !w.namenode.is_decommissioning(**r)
                })
                .count();
            !b.replicas.contains(&target) && effective < cap
        }
        None => false,
    };
    if short {
        w.namenode.add_replica(file, block_idx, target);
        w.faults.stats.rereplications_done += 1;
    }
    short
}

/// Plan a target for one [`ReplTask`] (excluding same-batch picks for
/// the same block via `planned`), account the recovery stats, and start
/// the `recovery:*` transfer; the landing commit runs
/// [`commit_replica`] followed by `epilogue` (world still borrowed).
/// Returns the chosen target, or None when no eligible non-holder is
/// left (tiny or half-dead cluster) — the block then stays
/// under-replicated. Shared by the crash scan, the re-join
/// under-replication scan, and the decommission drain.
fn plan_and_start(
    engine: &mut Engine,
    world: &WorldHandle,
    t: &ReplTask,
    planned: &mut BTreeMap<u64, Vec<NodeId>>,
    epilogue: impl FnOnce(&mut Engine, &mut crate::hdfs::World) + 'static,
) -> Option<NodeId> {
    let mut exclude = t.holders.clone();
    if let Some(p) = planned.get(&t.block_id) {
        exclude.extend_from_slice(p);
    }
    let target = pick_target(engine, world, t.block_id, &exclude)?;
    planned.entry(t.block_id).or_default().push(target);
    {
        let mut w = world.borrow_mut();
        w.faults.stats.rereplications_started += 1;
        w.faults.stats.recovery_bytes += t.bytes.max(1.0);
    }
    let file = t.file.clone();
    let block_idx = t.block_idx;
    start_transfer(engine, world, t.source, target, t.bytes, "recovery", None, move |engine, w| {
        commit_replica(w, &file, block_idx, target);
        epilogue(engine, w);
    });
    Some(target)
}

/// Start one `recovery:*` transfer per [`ReplTask`], each toward a live
/// non-holder target. Targets already chosen for a block in this batch
/// are excluded from later picks of the same block (nothing commits
/// until the transfers land, so the metadata cannot exclude them).
/// Shared by the crash scan and the re-join under-replication scan.
pub(crate) fn start_repl_tasks(engine: &mut Engine, world: &WorldHandle, tasks: Vec<ReplTask>) {
    let mut planned: BTreeMap<u64, Vec<NodeId>> = BTreeMap::new();
    for t in &tasks {
        let _ = plan_and_start(engine, world, t, &mut planned, |_, _| {});
    }
}

/// Deterministically choose an eligible DataNode (live, not draining)
/// that does not already hold the block: shuffle the candidates on a
/// block-id-keyed RNG stream.
/// On a multi-rack topology, when every surviving holder sits in one
/// rack the target is drawn from *another* rack where possible — repair
/// restores the rack-aware "spans two racks" invariant instead of
/// re-concentrating the block in the surviving failure domain (and the
/// transfer then crosses the oversubscribed fabric, as it must).
pub(crate) fn pick_target(
    engine: &mut Engine,
    world: &WorldHandle,
    block_id: u64,
    holders: &[NodeId],
) -> Option<NodeId> {
    let mut cands: Vec<NodeId> = {
        let w = world.borrow();
        let mut cands: Vec<NodeId> = w
            .namenode
            .target_datanodes()
            .into_iter()
            .filter(|n| !holders.contains(n))
            .collect();
        if w.namenode.rack_aware() && !holders.is_empty() {
            let r0 = w.namenode.rack_of(holders[0]);
            if holders.iter().all(|h| w.namenode.rack_of(*h) == r0) {
                let cross: Vec<NodeId> =
                    cands.iter().copied().filter(|n| w.namenode.rack_of(*n) != r0).collect();
                if !cross.is_empty() {
                    cands = cross;
                }
            }
        }
        cands
    };
    if cands.is_empty() {
        return None;
    }
    let mut rng = engine.rng.fork(0x4EC0 ^ block_id);
    rng.shuffle(&mut cands);
    cands.pop()
}

/// Restore a freshly committed block to the replication factor after a
/// mid-block pipeline failover shrank its pipeline (called by the HDFS
/// client right after the commit). Like the crash-scan path, the new
/// replica is committed only when its transfer completes with the
/// target still alive — a copy cut short by a later crash must not
/// leave a phantom replica in the metadata.
pub fn top_up_block(
    engine: &mut Engine,
    world: &WorldHandle,
    file: &str,
    block_idx: usize,
    replication: usize,
) {
    // Targets chosen in this call, so repeated shortfalls pick distinct
    // nodes even though nothing is committed until the copies land.
    let mut planned: Vec<NodeId> = Vec::new();
    loop {
        let task = {
            let w = world.borrow();
            let Some(meta) = w.namenode.get_file(file) else { return };
            let Some(b) = meta.blocks.get(block_idx) else { return };
            let live = w.namenode.live_datanodes().len();
            if b.replicas.is_empty()
                || b.replicas.len() + planned.len() >= replication.min(live)
            {
                return;
            }
            (b.id, b.stored_size, b.replicas[0], b.replicas.clone())
        };
        let (block_id, bytes, source, mut holders) = task;
        holders.extend_from_slice(&planned);
        let Some(target) = pick_target(engine, world, block_id, &holders) else { return };
        planned.push(target);
        {
            let mut w = world.borrow_mut();
            w.faults.stats.rereplications_started += 1;
            w.faults.stats.recovery_bytes += bytes.max(1.0);
        }
        let file2 = file.to_string();
        start_transfer(engine, world, source, target, bytes, "recovery", None, move |_engine, w| {
            commit_replica(w, &file2, block_idx, target);
        });
    }
}

/// Stream `bytes` of one block `source` → `target` (the NameNode repair
/// path: DataNode-to-DataNode, no client in the loop) and run `commit`
/// on completion with the world borrowed mutably. `class_prefix` names
/// the usage classes (`"recovery"` for repair, `"balance"` for balancer
/// moves) so the energy layer can attribute each separately;
/// `rate_cap_bps` throttles the transfer (the balancer's
/// `dfs.balance.bandwidthPerSec` cap). Callers account their own stats.
pub(crate) fn start_transfer(
    engine: &mut Engine,
    world: &WorldHandle,
    source: NodeId,
    target: NodeId,
    bytes: f64,
    class_prefix: &str,
    rate_cap_bps: Option<f64>,
    commit: impl FnOnce(&mut Engine, &mut crate::hdfs::World) + 'static,
) {
    let bytes = bytes.max(1.0);
    // Static category / histogram names per transfer kind (the span and
    // the closure must not borrow `class_prefix`).
    let (cat, hist, ctr): (&'static str, &'static str, &'static str) =
        if class_prefix == "balance" {
            ("balance", "balance.transfer_s", "balance.transfers")
        } else {
            ("recovery", "recovery.transfer_s", "recovery.transfers")
        };
    let span = if engine.spans_enabled() {
        engine.span_begin(cat, format!("{cat}:blk n{}->n{}", source.0, target.0), target.0 as u32)
    } else {
        crate::obs::SpanId::NONE
    };
    let t0 = engine.now();
    let spec = {
        let mut w = world.borrow_mut();
        w.cluster.disk_stream_start(engine, source, true);
        w.cluster.disk_stream_start(engine, target, false);
        let c_xfer = engine.class(&format!("{class_prefix}:xfer"));
        let c_send = engine.class(&format!("{class_prefix}:net-send"));
        let c_recv = engine.class(&format!("{class_prefix}:net-recv"));
        let c_write = engine.class(&format!("{class_prefix}:write-user"));
        let cluster = &w.cluster;
        let s = cluster.node(source);
        let d = cluster.node(target);
        let scosts = s.spec.cpu.costs.clone();
        let dcosts = d.spec.cpu.costs.clone();
        // Source: disk read + stream stack + socket send. Target: socket
        // receive + checksum verify + buffered write. One xceiver thread
        // per side.
        let src_cost = scosts.buffered_read + scosts.hadoop_stream + scosts.net_send_remote;
        let dst_cost = dcosts.net_recv_remote
            + dcosts.crc32
            + dcosts.hadoop_stream
            + dcosts.buffered_write_user;
        let mut f = FlowSpec::with_capacity(
            bytes,
            format!("{class_prefix}:blk n{}->n{}", source.0, target.0),
            10,
        )
        .demand(s.disk, 1.0 / s.spec.data_disk.read_bps, c_xfer)
        .demand(s.cpu, src_cost, c_send)
        .demand(s.nic_tx, 1.0, c_send)
        .demand(d.nic_rx, 1.0, c_recv)
        .demand(d.cpu, dst_cost, c_recv)
        .demand(d.disk, 1.0 / d.spec.data_disk.write_bps, c_write)
        .demand(d.membus, 1.0, c_xfer)
        .cap(1.0 / src_cost)
        .cap(1.0 / dst_cost);
        if let Some(cap) = rate_cap_bps {
            f = f.cap(cap);
        }
        // Cross-rack repair traffic traverses the (possibly
        // oversubscribed) ToR uplinks — after a whole-rack loss every
        // re-replication crosses the fabric.
        if let Some((up, down)) = cluster.cross_rack(source, target) {
            f = f.demand(up, 1.0, c_send).demand(down, 1.0, c_recv);
        }
        f
    };
    let world2 = world.clone();
    engine.start_flow(spec, move |engine| {
        engine.span_end(span);
        if engine.metrics_enabled() {
            let dur = engine.now() - t0;
            engine.metric_duration(hist, dur);
            engine.metric_incr(ctr, 1);
        }
        let mut w = world2.borrow_mut();
        w.cluster.disk_stream_end(engine, source, true);
        w.cluster.disk_stream_end(engine, target, false);
        commit(engine, &mut w);
    });
}
