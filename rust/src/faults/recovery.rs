//! Crash orchestration and NameNode-driven block re-replication.
//!
//! A crash is handled in four strictly ordered steps, all inside one
//! engine batch (a single simulated instant, one rate solve):
//!
//! 1. mark the node dead (fault state + NameNode blacklist), so every
//!    subsequent placement / replica pick avoids it;
//! 2. run the registered protocol failover handlers (in-flight HDFS
//!    writes rebuild their pipeline over the survivors, reads re-point
//!    at a surviving replica, the job scheduler blacklists the
//!    TaskTracker and re-queues its work);
//! 3. cancel every remaining flow touching the dead node's resources —
//!    the kill-switch for work no handler claimed (tasks running *on*
//!    the node, shuffle fetches served by it);
//! 4. start re-replication transfers for every block that lost a
//!    replica, sourced from the first surviving copy (deterministic) to
//!    a live non-holder target.
//!
//! Recovery transfers carry `recovery:*` usage classes so the energy
//! layer can attribute their joules separately
//! ([`crate::energy::EnergyReport::recovery_joules`]).
//!
//! Simplification: a transfer whose source or target dies mid-copy is
//! cancelled by that crash's kill-switch; the next scan retries from
//! the survivors (the one leaked disk-stream count on the surviving
//! endpoint only matters for the HDD seek model and only after a
//! double crash).

use std::collections::HashMap;

use crate::cluster::NodeId;
use crate::hdfs::WorldHandle;
use crate::sim::{Engine, FlowSpec};

use super::dispatch_crash;

/// Process a node-crash fault event end to end. Idempotent: a second
/// crash of the same node is a no-op.
pub fn handle_crash(engine: &mut Engine, world: &WorldHandle, node: NodeId) {
    {
        let mut w = world.borrow_mut();
        if !w.faults.set_down(node) {
            return;
        }
        w.faults.stats.crashes += 1;
        w.namenode.mark_dead(node);
    }
    let world2 = world.clone();
    engine.batch(move |engine| {
        dispatch_crash(engine, &world2, node);
        let resources = {
            let w = world2.borrow();
            w.cluster.node_resources(node)
        };
        for r in resources {
            engine.cancel_flows_on(r);
        }
        start_rereplication(engine, &world2, &[node]);
    });
}

/// Process a straggler fault event: the node's CPU drops to `factor`
/// of nominal capacity (dead nodes are skipped).
pub fn handle_straggle(engine: &mut Engine, world: &WorldHandle, node: NodeId, factor: f64) {
    let cpu = {
        let mut w = world.borrow_mut();
        if !w.faults.is_up(node) {
            return;
        }
        w.faults.stats.stragglers += 1;
        w.cluster.node(node).cpu
    };
    let cap = engine.resource(cpu).capacity;
    engine.set_capacity(cpu, cap * factor.clamp(0.01, 1.0));
}

/// Process a disk-degrade fault event (dead nodes are skipped).
pub fn handle_disk_degrade(engine: &mut Engine, world: &WorldHandle, node: NodeId, factor: f64) {
    let mut w = world.borrow_mut();
    if !w.faults.is_up(node) {
        return;
    }
    w.faults.stats.disk_degrades += 1;
    let f = factor.clamp(0.01, 1.0);
    w.cluster.set_disk_degrade(engine, node, f);
}

/// Process a whole-rack failure: every node in `rack` dies at once
/// together with the rack's ToR uplink. The master (node 0) is spared —
/// a master failure is a whole-job failure, out of scope for this model
/// — and the last live DataNode is never killed (a dead cluster can
/// neither place replicas nor finish a job).
///
/// Unlike a sequence of single crashes, the whole dead set is marked
/// *before* any failover handler runs, so pipeline rebuilds, replica
/// picks and re-replication targets already avoid the entire failure
/// domain — which is exactly why rack-aware placement keeps every block
/// recoverable, and why all the repair traffic crosses the (possibly
/// oversubscribed) fabric.
pub fn handle_rack_crash(engine: &mut Engine, world: &WorldHandle, rack: usize) {
    let members: Vec<NodeId> = {
        let w = world.borrow();
        // Rack faults are meaningless on the flat single-rack topology
        // (rack 0 would be the entire cluster) and on unknown indices.
        if w.cluster.racks() <= 1 || rack >= w.cluster.racks() {
            return;
        }
        w.cluster.rack_nodes(rack).into_iter().filter(|n| n.0 != 0).collect()
    };
    let mut newly_dead: Vec<NodeId> = Vec::new();
    {
        let mut w = world.borrow_mut();
        w.faults.stats.rack_crashes += 1;
        for &n in &members {
            if !w.faults.is_up(n) {
                continue;
            }
            if w.namenode.is_datanode(n) && w.namenode.live_datanodes().len() <= 1 {
                continue; // keep the last live DataNode alive
            }
            let _ = w.faults.set_down(n);
            w.namenode.mark_dead(n);
            w.faults.stats.crashes += 1;
            newly_dead.push(n);
        }
    }
    // A member can be spared (already dead, or the last live DataNode).
    // Only when the rack is genuinely empty of live nodes does its ToR
    // go dark — draining the uplink under a live spared member would
    // cancel its in-flight cross-rack flows with no failover dispatched
    // for it, silently stranding those protocol chains.
    let all_members_down = {
        let w = world.borrow();
        !members.is_empty() && members.iter().all(|&n| !w.faults.is_up(n))
    };
    let world2 = world.clone();
    engine.batch(move |engine| {
        // The ToR uplink goes dark: drain in-flight cross-rack flows and
        // floor the capacity. With every member dead nothing can start a
        // new flow across it; the 1% floor merely keeps rate solving
        // well-conditioned if one ever did.
        let uplink = {
            let w = world2.borrow();
            w.cluster.rack_uplink(rack).map(|u| (u.up, u.down))
        };
        if let Some((up, down)) = uplink.filter(|_| all_members_down) {
            engine.cancel_flows_on(up);
            engine.cancel_flows_on(down);
            let mut w = world2.borrow_mut();
            w.cluster.set_uplink_degrade(engine, rack, 0.01);
        }
        // Protocol failovers plus the flow kill-switch, per dead node.
        for &n in &newly_dead {
            dispatch_crash(engine, &world2, n);
            let resources = {
                let w = world2.borrow();
                w.cluster.node_resources(n)
            };
            for r in resources {
                engine.cancel_flows_on(r);
            }
        }
        // Re-replicate everything the rack held in one scan (so two
        // same-instant repairs of one block pick distinct targets);
        // targets already exclude the whole rack, so every transfer
        // crosses the fabric.
        start_rereplication(engine, &world2, &newly_dead);
    });
}

/// Process a ToR-uplink brownout: the rack's uplink capacity dips to
/// `factor` of nominal in both directions (in-flight cross-rack flows
/// simply re-solve at the new rate). Brownouts only ever *lower*
/// capacity — a dip arriving after a whole-rack crash (or a deeper
/// earlier brownout) must not revive the floored uplink. Flat
/// topologies and unknown rack indices are no-ops.
pub fn handle_rack_brownout(engine: &mut Engine, world: &WorldHandle, rack: usize, factor: f64) {
    let mut w = world.borrow_mut();
    let current = match w.cluster.rack_uplink(rack) {
        Some(u) => u.degrade,
        None => return,
    };
    w.faults.stats.rack_brownouts += 1;
    w.cluster.set_uplink_degrade(engine, rack, factor.clamp(0.01, 1.0).min(current));
}

/// Scan the namespace for blocks that lost a replica on any of `dead`
/// and start one transfer per recoverable lost copy; blocks whose last
/// replica died are counted lost. All the dead nodes of one failure
/// instant must come through a **single** call: a block that lost two
/// replicas at once (whole-rack crash) spawns two same-instant repairs,
/// and the second must exclude the first's in-flight target —
/// `add_replica` dedupes, so a collision would leave the block
/// permanently under-replicated while the stats counted two repairs.
fn start_rereplication(engine: &mut Engine, world: &WorldHandle, dead: &[NodeId]) {
    let tasks = {
        let mut w = world.borrow_mut();
        let mut tasks = Vec::new();
        for &d in dead {
            tasks.extend(w.namenode.purge_node(d));
        }
        tasks
    };
    // Targets already chosen for a block in this scan (nothing commits
    // until the transfers land, so the metadata cannot exclude them).
    let mut planned: HashMap<u64, Vec<NodeId>> = HashMap::new();
    for t in &tasks {
        let mut exclude = t.holders.clone();
        if let Some(p) = planned.get(&t.block_id) {
            exclude.extend_from_slice(p);
        }
        if let Some(target) = pick_target(engine, world, t.block_id, &exclude) {
            planned.entry(t.block_id).or_default().push(target);
            let file = t.file.clone();
            let block_idx = t.block_idx;
            start_transfer(engine, world, t.source, target, t.bytes, move |_engine, w| {
                // Commit only if the target survived the copy; a dead
                // target is retried by the next crash's scan.
                if w.faults.is_up(target) {
                    w.namenode.add_replica(&file, block_idx, target);
                    w.faults.stats.rereplications_done += 1;
                }
            });
        }
        // else: no live non-holder left (tiny cluster) — the block
        // stays under-replicated.
    }
    let mut w = world.borrow_mut();
    let lost = w
        .namenode
        .files()
        .flat_map(|(_, f)| f.blocks.iter())
        .filter(|b| b.replicas.is_empty())
        .count();
    if lost > w.faults.stats.blocks_lost {
        w.faults.stats.blocks_lost = lost;
    }
}

/// Deterministically choose a live DataNode that does not already hold
/// the block: shuffle the candidates on a block-id-keyed RNG stream.
/// On a multi-rack topology, when every surviving holder sits in one
/// rack the target is drawn from *another* rack where possible — repair
/// restores the rack-aware "spans two racks" invariant instead of
/// re-concentrating the block in the surviving failure domain (and the
/// transfer then crosses the oversubscribed fabric, as it must).
fn pick_target(
    engine: &mut Engine,
    world: &WorldHandle,
    block_id: u64,
    holders: &[NodeId],
) -> Option<NodeId> {
    let mut cands: Vec<NodeId> = {
        let w = world.borrow();
        let mut cands: Vec<NodeId> = w
            .namenode
            .live_datanodes()
            .into_iter()
            .filter(|n| !holders.contains(n))
            .collect();
        if w.namenode.rack_aware() && !holders.is_empty() {
            let r0 = w.namenode.rack_of(holders[0]);
            if holders.iter().all(|h| w.namenode.rack_of(*h) == r0) {
                let cross: Vec<NodeId> =
                    cands.iter().copied().filter(|n| w.namenode.rack_of(*n) != r0).collect();
                if !cross.is_empty() {
                    cands = cross;
                }
            }
        }
        cands
    };
    if cands.is_empty() {
        return None;
    }
    let mut rng = engine.rng.fork(0x4EC0 ^ block_id);
    rng.shuffle(&mut cands);
    cands.pop()
}

/// Restore a freshly committed block to the replication factor after a
/// mid-block pipeline failover shrank its pipeline (called by the HDFS
/// client right after the commit). Like the crash-scan path, the new
/// replica is committed only when its transfer completes with the
/// target still alive — a copy cut short by a later crash must not
/// leave a phantom replica in the metadata.
pub fn top_up_block(
    engine: &mut Engine,
    world: &WorldHandle,
    file: &str,
    block_idx: usize,
    replication: usize,
) {
    // Targets chosen in this call, so repeated shortfalls pick distinct
    // nodes even though nothing is committed until the copies land.
    let mut planned: Vec<NodeId> = Vec::new();
    loop {
        let task = {
            let w = world.borrow();
            let Some(meta) = w.namenode.get_file(file) else { return };
            let Some(b) = meta.blocks.get(block_idx) else { return };
            let live = w.namenode.live_datanodes().len();
            if b.replicas.is_empty()
                || b.replicas.len() + planned.len() >= replication.min(live)
            {
                return;
            }
            (b.id, b.stored_size, b.replicas[0], b.replicas.clone())
        };
        let (block_id, bytes, source, mut holders) = task;
        holders.extend_from_slice(&planned);
        let Some(target) = pick_target(engine, world, block_id, &holders) else { return };
        planned.push(target);
        let file2 = file.to_string();
        start_transfer(engine, world, source, target, bytes, move |_engine, w| {
            if w.faults.is_up(target) {
                w.namenode.add_replica(&file2, block_idx, target);
                w.faults.stats.rereplications_done += 1;
            }
        });
    }
}

/// Stream `bytes` of one block `source` → `target` (the NameNode repair
/// path: DataNode-to-DataNode, no client in the loop) and run `commit`
/// on completion with the world borrowed mutably.
fn start_transfer(
    engine: &mut Engine,
    world: &WorldHandle,
    source: NodeId,
    target: NodeId,
    bytes: f64,
    commit: impl FnOnce(&mut Engine, &mut crate::hdfs::World) + 'static,
) {
    let bytes = bytes.max(1.0);
    let spec = {
        let mut w = world.borrow_mut();
        w.faults.stats.rereplications_started += 1;
        w.faults.stats.recovery_bytes += bytes;
        w.cluster.disk_stream_start(engine, source, true);
        w.cluster.disk_stream_start(engine, target, false);
        let cluster = &w.cluster;
        let s = cluster.node(source);
        let d = cluster.node(target);
        let scosts = s.spec.cpu.costs.clone();
        let dcosts = d.spec.cpu.costs.clone();
        let c_xfer = engine.class("recovery:xfer");
        let c_send = engine.class("recovery:net-send");
        let c_recv = engine.class("recovery:net-recv");
        let c_write = engine.class("recovery:write-user");
        // Source: disk read + stream stack + socket send. Target: socket
        // receive + checksum verify + buffered write. One xceiver thread
        // per side.
        let src_cost = scosts.buffered_read + scosts.hadoop_stream + scosts.net_send_remote;
        let dst_cost = dcosts.net_recv_remote
            + dcosts.crc32
            + dcosts.hadoop_stream
            + dcosts.buffered_write_user;
        let mut f = FlowSpec::with_capacity(
            bytes,
            format!("recovery:blk n{}->n{}", source.0, target.0),
            10,
        )
        .demand(s.disk, 1.0 / s.spec.data_disk.read_bps, c_xfer)
        .demand(s.cpu, src_cost, c_send)
        .demand(s.nic_tx, 1.0, c_send)
        .demand(d.nic_rx, 1.0, c_recv)
        .demand(d.cpu, dst_cost, c_recv)
        .demand(d.disk, 1.0 / d.spec.data_disk.write_bps, c_write)
        .demand(d.membus, 1.0, c_xfer)
        .cap(1.0 / src_cost)
        .cap(1.0 / dst_cost);
        // Cross-rack repair traffic traverses the (possibly
        // oversubscribed) ToR uplinks — after a whole-rack loss every
        // re-replication crosses the fabric.
        if let Some((up, down)) = cluster.cross_rack(source, target) {
            f = f.demand(up, 1.0, c_send).demand(down, 1.0, c_recv);
        }
        f
    };
    let world2 = world.clone();
    engine.start_flow(spec, move |engine| {
        let mut w = world2.borrow_mut();
        w.cluster.disk_stream_end(engine, source, true);
        w.cluster.disk_stream_end(engine, target, false);
        commit(engine, &mut w);
    });
}
