//! Crash orchestration and NameNode-driven block re-replication.
//!
//! A crash is handled in four strictly ordered steps, all inside one
//! engine batch (a single simulated instant, one rate solve):
//!
//! 1. mark the node dead (fault state + NameNode blacklist), so every
//!    subsequent placement / replica pick avoids it;
//! 2. run the registered protocol failover handlers (in-flight HDFS
//!    writes rebuild their pipeline over the survivors, reads re-point
//!    at a surviving replica, the job scheduler blacklists the
//!    TaskTracker and re-queues its work);
//! 3. cancel every remaining flow touching the dead node's resources —
//!    the kill-switch for work no handler claimed (tasks running *on*
//!    the node, shuffle fetches served by it);
//! 4. start re-replication transfers for every block that lost a
//!    replica, sourced from the first surviving copy (deterministic) to
//!    a live non-holder target.
//!
//! Recovery transfers carry `recovery:*` usage classes so the energy
//! layer can attribute their joules separately
//! ([`crate::energy::EnergyReport::recovery_joules`]).
//!
//! Simplification: a transfer whose source or target dies mid-copy is
//! cancelled by that crash's kill-switch; the next scan retries from
//! the survivors (the one leaked disk-stream count on the surviving
//! endpoint only matters for the HDD seek model and only after a
//! double crash).

use crate::cluster::NodeId;
use crate::hdfs::WorldHandle;
use crate::sim::{Engine, FlowSpec};

use super::dispatch_crash;

/// Process a node-crash fault event end to end. Idempotent: a second
/// crash of the same node is a no-op.
pub fn handle_crash(engine: &mut Engine, world: &WorldHandle, node: NodeId) {
    {
        let mut w = world.borrow_mut();
        if !w.faults.set_down(node) {
            return;
        }
        w.faults.stats.crashes += 1;
        w.namenode.mark_dead(node);
    }
    let world2 = world.clone();
    engine.batch(move |engine| {
        dispatch_crash(engine, &world2, node);
        let resources = {
            let w = world2.borrow();
            w.cluster.node_resources(node)
        };
        for r in resources {
            engine.cancel_flows_on(r);
        }
        start_rereplication(engine, &world2, node);
    });
}

/// Process a straggler fault event: the node's CPU drops to `factor`
/// of nominal capacity (dead nodes are skipped).
pub fn handle_straggle(engine: &mut Engine, world: &WorldHandle, node: NodeId, factor: f64) {
    let cpu = {
        let mut w = world.borrow_mut();
        if !w.faults.is_up(node) {
            return;
        }
        w.faults.stats.stragglers += 1;
        w.cluster.node(node).cpu
    };
    let cap = engine.resource(cpu).capacity;
    engine.set_capacity(cpu, cap * factor.clamp(0.01, 1.0));
}

/// Process a disk-degrade fault event (dead nodes are skipped).
pub fn handle_disk_degrade(engine: &mut Engine, world: &WorldHandle, node: NodeId, factor: f64) {
    let mut w = world.borrow_mut();
    if !w.faults.is_up(node) {
        return;
    }
    w.faults.stats.disk_degrades += 1;
    let f = factor.clamp(0.01, 1.0);
    w.cluster.set_disk_degrade(engine, node, f);
}

/// Scan the namespace for blocks that lost a replica on `dead` and
/// start one transfer per recoverable block; blocks whose last replica
/// died are counted lost.
fn start_rereplication(engine: &mut Engine, world: &WorldHandle, dead: NodeId) {
    let tasks = {
        let mut w = world.borrow_mut();
        w.namenode.purge_node(dead)
    };
    for t in &tasks {
        if let Some(target) = pick_target(engine, world, t.block_id, &t.holders) {
            let file = t.file.clone();
            let block_idx = t.block_idx;
            start_transfer(engine, world, t.source, target, t.bytes, move |_engine, w| {
                // Commit only if the target survived the copy; a dead
                // target is retried by the next crash's scan.
                if w.faults.is_up(target) {
                    w.namenode.add_replica(&file, block_idx, target);
                    w.faults.stats.rereplications_done += 1;
                }
            });
        }
        // else: no live non-holder left (tiny cluster) — the block
        // stays under-replicated.
    }
    let mut w = world.borrow_mut();
    let lost = w
        .namenode
        .files()
        .flat_map(|(_, f)| f.blocks.iter())
        .filter(|b| b.replicas.is_empty())
        .count();
    if lost > w.faults.stats.blocks_lost {
        w.faults.stats.blocks_lost = lost;
    }
}

/// Deterministically choose a live DataNode that does not already hold
/// the block: shuffle the candidates on a block-id-keyed RNG stream.
fn pick_target(
    engine: &mut Engine,
    world: &WorldHandle,
    block_id: u64,
    holders: &[NodeId],
) -> Option<NodeId> {
    let mut cands: Vec<NodeId> = {
        let w = world.borrow();
        w.namenode
            .live_datanodes()
            .into_iter()
            .filter(|n| !holders.contains(n))
            .collect()
    };
    if cands.is_empty() {
        return None;
    }
    let mut rng = engine.rng.fork(0x4EC0 ^ block_id);
    rng.shuffle(&mut cands);
    cands.pop()
}

/// Restore a freshly committed block to the replication factor after a
/// mid-block pipeline failover shrank its pipeline (called by the HDFS
/// client right after the commit). Like the crash-scan path, the new
/// replica is committed only when its transfer completes with the
/// target still alive — a copy cut short by a later crash must not
/// leave a phantom replica in the metadata.
pub fn top_up_block(
    engine: &mut Engine,
    world: &WorldHandle,
    file: &str,
    block_idx: usize,
    replication: usize,
) {
    // Targets chosen in this call, so repeated shortfalls pick distinct
    // nodes even though nothing is committed until the copies land.
    let mut planned: Vec<NodeId> = Vec::new();
    loop {
        let task = {
            let w = world.borrow();
            let Some(meta) = w.namenode.get_file(file) else { return };
            let Some(b) = meta.blocks.get(block_idx) else { return };
            let live = w.namenode.live_datanodes().len();
            if b.replicas.is_empty()
                || b.replicas.len() + planned.len() >= replication.min(live)
            {
                return;
            }
            (b.id, b.stored_size, b.replicas[0], b.replicas.clone())
        };
        let (block_id, bytes, source, mut holders) = task;
        holders.extend_from_slice(&planned);
        let Some(target) = pick_target(engine, world, block_id, &holders) else { return };
        planned.push(target);
        let file2 = file.to_string();
        start_transfer(engine, world, source, target, bytes, move |_engine, w| {
            if w.faults.is_up(target) {
                w.namenode.add_replica(&file2, block_idx, target);
                w.faults.stats.rereplications_done += 1;
            }
        });
    }
}

/// Stream `bytes` of one block `source` → `target` (the NameNode repair
/// path: DataNode-to-DataNode, no client in the loop) and run `commit`
/// on completion with the world borrowed mutably.
fn start_transfer(
    engine: &mut Engine,
    world: &WorldHandle,
    source: NodeId,
    target: NodeId,
    bytes: f64,
    commit: impl FnOnce(&mut Engine, &mut crate::hdfs::World) + 'static,
) {
    let bytes = bytes.max(1.0);
    let spec = {
        let mut w = world.borrow_mut();
        w.faults.stats.rereplications_started += 1;
        w.faults.stats.recovery_bytes += bytes;
        w.cluster.disk_stream_start(engine, source, true);
        w.cluster.disk_stream_start(engine, target, false);
        let cluster = &w.cluster;
        let s = cluster.node(source);
        let d = cluster.node(target);
        let scosts = s.spec.cpu.costs.clone();
        let dcosts = d.spec.cpu.costs.clone();
        let c_xfer = engine.class("recovery:xfer");
        let c_send = engine.class("recovery:net-send");
        let c_recv = engine.class("recovery:net-recv");
        let c_write = engine.class("recovery:write-user");
        // Source: disk read + stream stack + socket send. Target: socket
        // receive + checksum verify + buffered write. One xceiver thread
        // per side.
        let src_cost = scosts.buffered_read + scosts.hadoop_stream + scosts.net_send_remote;
        let dst_cost = dcosts.net_recv_remote
            + dcosts.crc32
            + dcosts.hadoop_stream
            + dcosts.buffered_write_user;
        FlowSpec::with_capacity(bytes, format!("recovery:blk n{}->n{}", source.0, target.0), 8)
            .demand(s.disk, 1.0 / s.spec.data_disk.read_bps, c_xfer)
            .demand(s.cpu, src_cost, c_send)
            .demand(s.nic_tx, 1.0, c_send)
            .demand(d.nic_rx, 1.0, c_recv)
            .demand(d.cpu, dst_cost, c_recv)
            .demand(d.disk, 1.0 / d.spec.data_disk.write_bps, c_write)
            .demand(d.membus, 1.0, c_xfer)
            .cap(1.0 / src_cost)
            .cap(1.0 / dst_cost)
    };
    let world2 = world.clone();
    engine.start_flow(spec, move |engine| {
        let mut w = world2.borrow_mut();
        w.cluster.disk_stream_end(engine, source, true);
        w.cluster.disk_stream_end(engine, target, false);
        commit(engine, &mut w);
    });
}
