//! Fault injection & recovery: datanode crashes, re-replication,
//! stragglers, and speculative execution.
//!
//! The paper's efficiency numbers are measured on fault-free runs, but
//! the whole reason HDFS triples every written byte is failure
//! tolerance. This subsystem closes the loop: a seeded [`InjectionPlan`]
//! schedules crashes, CPU stragglers and disk degrades into the engine;
//! the HDFS layer reacts with dead-node detection, **write-pipeline
//! failover mid-block** and **block re-replication** from surviving
//! copies; the MapReduce layer reacts with TaskTracker blacklisting,
//! re-execution of lost map outputs, and Hadoop-0.20-style speculative
//! execution of straggling maps (progress-rate threshold, kill-loser).
//!
//! * [`plan`] — [`InjectionPlan`] → deterministic [`FaultSchedule`]
//!   (all sampling on a dedicated RNG stream keyed by the scenario's
//!   stable id, so faults are identical across thread counts and
//!   [`crate::sim::SolverMode`]s);
//! * [`injector`] — schedules the fault events as engine timers;
//! * [`recovery`] — crash and lifecycle orchestration: mark the node
//!   dead, run the registered protocol failover handlers, kill every
//!   remaining flow touching the node, re-replicate under-replicated
//!   blocks — plus the full **node lifecycle**: graceful decommission
//!   (drain → administratively dead) and recommission (block report,
//!   TaskTracker re-registration, resource re-arm);
//! * [`balancer`] — the v0.20-style background **rack-aware balancer**:
//!   threshold-based, bandwidth-capped replica moves from over- to
//!   under-utilized DataNodes, rack-spread-preserving, attributed as
//!   `balance:*` usage classes ([`crate::energy::EnergyReport::balance_joules`]).
//!
//! **Identity invariant:** with an empty plan nothing is installed — no
//! timers, no RNG draws, no extra state transitions — so fault-free
//! output (including `BENCH_sweep.json`) is byte-identical to a build
//! without this subsystem. `tests/integration_faults.rs` pins this.
//!
//! Modeling conventions (documented simplifications):
//!
//! * Crashed nodes stay dead unless the plan schedules a recommission
//!   (`rejoin_after_s` or fixed [`RecommissionSpec`] entries);
//!   re-replication restores the replica count on the survivors either
//!   way (Hadoop's NameNode repair path), and a re-joining node's
//!   now-redundant copies are invalidated by its block report.
//! * A v0.20 pipeline that loses a DataNode continues on the surviving
//!   replicas for the in-flight block (stock recovery semantics); the
//!   committed block is topped back up to the replication factor by an
//!   immediate re-replication transfer.
//! * Killed task attempts stop at their next phase boundary; flows
//!   already in flight on healthy nodes run out (their time is counted
//!   as wasted work), while flows touching the dead node are cancelled
//!   at the instant of the crash.

pub mod balancer;
pub mod injector;
pub mod plan;
pub mod recovery;

pub use injector::install;
pub use plan::{
    fault_stream_seed, BalancerConfig, CrashSpec, DecommissionSpec, FaultEvent, FaultKind,
    FaultSchedule, InjectionPlan, RackBrownoutSpec, RackCrashSpec, RecommissionSpec,
};

use crate::cluster::NodeId;
use crate::sim::Engine;

/// A protocol-layer crash reaction (in-flight HDFS write/read failover,
/// job-scheduler blacklisting). Called once per crash with the dead
/// node; returning `false` deregisters the handler.
pub type FailoverHandler = Box<dyn FnMut(&mut Engine, NodeId) -> bool>;

/// Counters describing what the fault subsystem did to a run. Everything
/// here is deterministic for a given plan + stream seed.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultStats {
    /// Nodes that crashed.
    pub crashes: usize,
    /// Whole-rack failures processed (each also counts its member
    /// crashes in `crashes`).
    pub rack_crashes: usize,
    /// ToR-uplink brownouts applied.
    pub rack_brownouts: usize,
    /// Nodes slowed by a straggler event.
    pub stragglers: usize,
    /// Nodes whose data disk degraded.
    pub disk_degrades: usize,
    /// Block re-replication transfers started / completed.
    pub rereplications_started: usize,
    /// Block re-replication transfers completed and committed.
    pub rereplications_done: usize,
    /// Bytes moved by re-replication (wire bytes, stored size).
    pub recovery_bytes: f64,
    /// Blocks that lost every replica (unrecoverable; counted once per
    /// block by the post-crash namespace scan).
    pub blocks_lost: usize,
    /// Read attempts that hit a lost block and skipped it (one per
    /// attempted read, so re-reads count again).
    pub lost_block_reads: usize,
    /// In-flight write pipelines rebuilt around a dead DataNode.
    pub pipeline_failovers: usize,
    /// In-flight reads re-pointed at a surviving replica.
    pub read_failovers: usize,
    /// Whole-file writes abandoned because the writing client died.
    pub writes_aborted: usize,
    /// Map / reduce attempts re-queued after a TaskTracker death.
    pub maps_requeued: usize,
    /// Reduce attempts re-queued after a TaskTracker death.
    pub reduces_requeued: usize,
    /// Completed map outputs lost with their host and re-executed.
    pub map_outputs_lost: usize,
    /// Speculative map attempts launched.
    pub spec_launched: usize,
    /// Speculative attempts that beat the original.
    pub spec_wins: usize,
    /// Speculative attempts killed as losers.
    pub spec_wasted: usize,
    /// Simulated seconds of task work thrown away (killed attempts).
    pub wasted_task_seconds: f64,
    /// Graceful decommissions started.
    pub decommissions: usize,
    /// Nodes that re-joined the cluster (including cancelled
    /// decommissions of still-live nodes).
    pub recommissions: usize,
    /// TaskTrackers re-registered with a live job on re-join.
    pub trackers_rejoined: usize,
    /// Replicas re-registered by a re-join block report (blocks still on
    /// the returning node's intact disk that the namespace was missing).
    pub blocks_restored_on_rejoin: usize,
    /// Excess replicas invalidated (block-report copies made redundant
    /// by crash-time re-replication, plus over-replication scans).
    pub excess_replicas_dropped: usize,
    /// Balancer iterations that started at least one move.
    pub balancer_rounds: usize,
    /// Balancer block moves started / committed.
    pub balancer_moves_started: usize,
    /// Balancer block moves that completed and committed.
    pub balancer_moves_done: usize,
    /// Bytes moved by the balancer (wire bytes, stored size).
    pub balance_bytes: f64,
}

/// One in-flight balancer move, tracked so a later balancer round never
/// double-plans the same block and can account the bytes as already
/// moved when computing utilization.
#[derive(Debug, Clone, Copy)]
pub(crate) struct PendingMove {
    /// Block being moved.
    pub block_id: u64,
    /// Replica being vacated.
    pub source: NodeId,
    /// Node receiving the new copy.
    pub target: NodeId,
    /// Stored (wire) bytes of the block.
    pub bytes: f64,
}

/// Per-run fault state, owned by [`crate::hdfs::World`]. For fault-free
/// runs it stays inert: `active` is false, the handler lists are empty,
/// and no code path consults anything else.
pub struct FaultState {
    /// Per-node liveness (index = node id). Empty until the injector
    /// installs a schedule; [`FaultState::is_up`] treats missing entries
    /// as up, so fault-free runs never allocate.
    node_up: Vec<bool>,
    /// Per-node "last death was a crash" flag: a crash cancels the
    /// node's flows, so its disk-stream counters are garbage and must be
    /// reset on re-join; a graceful drain leaves them accurate.
    hard_down: Vec<bool>,
    /// True once a non-empty schedule was installed.
    pub active: bool,
    /// Speculative execution enabled (scheduler consults this).
    pub speculation: bool,
    /// Replication factor the recovery / re-join scans restore toward
    /// (`dfs.replication`; set by the world builders).
    pub replication: usize,
    /// Registered crash reactions, run in registration order.
    pub(crate) handlers: Vec<FailoverHandler>,
    /// Registered re-join reactions (TaskTracker re-registration).
    pub(crate) rejoin_handlers: Vec<FailoverHandler>,
    /// Registered decommission-drain reactions (stop scheduling onto the
    /// node; running attempts finish).
    pub(crate) drain_handlers: Vec<FailoverHandler>,
    /// Background balancer configuration; None = not installed.
    pub balancer: Option<plan::BalancerConfig>,
    /// Is the balancer poll chain currently scheduled? (It parks itself
    /// after a few idle rounds and is re-kicked by membership changes.)
    pub(crate) balancer_running: bool,
    /// Consecutive balancer polls that found nothing to move.
    pub(crate) balancer_idle_rounds: usize,
    /// In-flight balancer moves (started, not yet committed).
    pub(crate) balancer_pending: Vec<PendingMove>,
    /// In-flight decommission-drain copies (`source` = the draining
    /// node), so drain re-scans never double-copy a block and a crash
    /// that kills a copy's endpoint can restart the stalled drain.
    pub(crate) drain_pending: Vec<PendingMove>,
    /// Open `"lifecycle"` drain spans by node: begun at decommission,
    /// ended when the drain completes or is cancelled (span coverage
    /// for lifecycle transitions — instants alone don't show duration).
    pub(crate) drain_spans: Vec<(NodeId, crate::obs::SpanId)>,
    /// Counters describing everything the subsystem did.
    pub stats: FaultStats,
}

impl Default for FaultState {
    fn default() -> Self {
        FaultState::new()
    }
}

impl FaultState {
    /// Fresh, inert state (what fault-free runs keep forever).
    pub fn new() -> FaultState {
        FaultState {
            node_up: Vec::new(),
            hard_down: Vec::new(),
            active: false,
            speculation: false,
            replication: 3,
            handlers: Vec::new(),
            rejoin_handlers: Vec::new(),
            drain_handlers: Vec::new(),
            balancer: None,
            balancer_running: false,
            balancer_idle_rounds: 0,
            balancer_pending: Vec::new(),
            drain_pending: Vec::new(),
            drain_spans: Vec::new(),
            stats: FaultStats::default(),
        }
    }

    /// Arm the state for a cluster of `nodes` nodes (all up).
    pub(crate) fn arm(&mut self, nodes: usize, speculation: bool) {
        if self.node_up.len() < nodes {
            self.node_up.resize(nodes, true);
        }
        self.active = true;
        self.speculation = speculation;
    }

    /// Is `node` alive? Nodes never seen by the injector are always up.
    pub fn is_up(&self, node: NodeId) -> bool {
        self.node_up.get(node.0).copied().unwrap_or(true)
    }

    /// Mark `node` dead; returns false if it already was.
    pub(crate) fn set_down(&mut self, node: NodeId) -> bool {
        if self.node_up.len() <= node.0 {
            self.node_up.resize(node.0 + 1, true);
        }
        let was_up = self.node_up[node.0];
        self.node_up[node.0] = false;
        was_up
    }

    /// Mark `node` alive again; returns false if it already was.
    pub(crate) fn set_up(&mut self, node: NodeId) -> bool {
        if self.node_up.len() <= node.0 {
            return false; // never armed → always considered up
        }
        let was_down = !self.node_up[node.0];
        self.node_up[node.0] = true;
        was_down
    }

    /// Record that `node`'s death was a crash (flows cancelled).
    pub(crate) fn mark_hard(&mut self, node: NodeId) {
        if self.hard_down.len() <= node.0 {
            self.hard_down.resize(node.0 + 1, false);
        }
        self.hard_down[node.0] = true;
    }

    /// Consume the hard-crash flag for `node` (re-join reads it once).
    pub(crate) fn take_hard(&mut self, node: NodeId) -> bool {
        match self.hard_down.get_mut(node.0) {
            Some(h) => std::mem::take(h),
            None => false,
        }
    }

    /// Register a crash reaction. Handlers self-deregister by returning
    /// false (e.g. when the protocol operation they guard has finished).
    pub fn register(&mut self, h: FailoverHandler) {
        self.handlers.push(h);
    }

    /// Register a re-join reaction (run once per recommissioned node).
    pub fn register_rejoin(&mut self, h: FailoverHandler) {
        self.rejoin_handlers.push(h);
    }

    /// Register a decommission-drain reaction (run when a node enters
    /// the decommissioning state).
    pub fn register_drain(&mut self, h: FailoverHandler) {
        self.drain_handlers.push(h);
    }

    /// Purge in-flight balancer moves and drain copies touching any of
    /// `dead` (their flows die with the nodes, so their completion
    /// callbacks never run) and return the draining sources whose copy
    /// just lost its target — those drains must be restarted. Shared by
    /// the single-node and whole-rack crash paths.
    pub(crate) fn purge_pending_for_dead(&mut self, dead: &[NodeId]) -> Vec<NodeId> {
        let stalled: Vec<NodeId> = self
            .drain_pending
            .iter()
            .filter(|p| dead.contains(&p.target) && !dead.contains(&p.source))
            .map(|p| p.source)
            .collect();
        self.balancer_pending
            .retain(|p| !dead.contains(&p.source) && !dead.contains(&p.target));
        self.drain_pending
            .retain(|p| !dead.contains(&p.source) && !dead.contains(&p.target));
        stalled
    }
}

/// Which handler list a lifecycle dispatch runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum HandlerKind {
    Crash,
    Rejoin,
    Drain,
}

/// Run every registered handler of `kind` for `node`.
///
/// Handlers may borrow the world and may register *new* handlers while
/// running (a rebuilt pipeline re-arms its guard), so the list is taken
/// out of the world for the duration and merged back afterwards.
fn dispatch_kind(
    engine: &mut Engine,
    world: &crate::hdfs::WorldHandle,
    node: NodeId,
    kind: HandlerKind,
) {
    fn list(f: &mut FaultState, kind: HandlerKind) -> &mut Vec<FailoverHandler> {
        match kind {
            HandlerKind::Crash => &mut f.handlers,
            HandlerKind::Rejoin => &mut f.rejoin_handlers,
            HandlerKind::Drain => &mut f.drain_handlers,
        }
    }
    let mut handlers = std::mem::take(list(&mut world.borrow_mut().faults, kind));
    let mut kept: Vec<FailoverHandler> = Vec::with_capacity(handlers.len());
    for mut h in handlers.drain(..) {
        if h(engine, node) {
            kept.push(h);
        }
    }
    let mut w = world.borrow_mut();
    // Handlers registered during dispatch landed in the (emptied) world
    // list; keep them after the surviving originals so registration
    // order stays chronological.
    let new = std::mem::take(list(&mut w.faults, kind));
    let slot = list(&mut w.faults, kind);
    *slot = kept;
    slot.extend(new);
}

/// Run every registered failover handler for a crash of `node`.
pub fn dispatch_crash(engine: &mut Engine, world: &crate::hdfs::WorldHandle, node: NodeId) {
    dispatch_kind(engine, world, node, HandlerKind::Crash);
}

/// Run every registered re-join handler for a recommission of `node`.
pub fn dispatch_rejoin(engine: &mut Engine, world: &crate::hdfs::WorldHandle, node: NodeId) {
    dispatch_kind(engine, world, node, HandlerKind::Rejoin);
}

/// Run every registered drain handler for a decommission of `node`.
pub fn dispatch_drain(engine: &mut Engine, world: &crate::hdfs::WorldHandle, node: NodeId) {
    dispatch_kind(engine, world, node, HandlerKind::Drain);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_state_is_inert() {
        let s = FaultState::new();
        assert!(!s.active);
        assert!(!s.speculation);
        assert!(s.is_up(NodeId(0)));
        assert!(s.is_up(NodeId(99)));
        assert_eq!(s.stats, FaultStats::default());
    }

    #[test]
    fn arm_and_down_tracking() {
        let mut s = FaultState::new();
        s.arm(4, true);
        assert!(s.active && s.speculation);
        assert!(s.is_up(NodeId(3)));
        assert!(s.set_down(NodeId(3)));
        assert!(!s.is_up(NodeId(3)));
        assert!(!s.set_down(NodeId(3)), "second down is a no-op");
        assert!(s.is_up(NodeId(1)));
    }

    #[test]
    fn up_down_round_trip_and_hard_flag() {
        let mut s = FaultState::new();
        s.arm(4, false);
        assert!(s.set_down(NodeId(2)));
        s.mark_hard(NodeId(2));
        assert!(s.set_up(NodeId(2)), "set_up reports the transition");
        assert!(s.is_up(NodeId(2)));
        assert!(!s.set_up(NodeId(2)), "second up is a no-op");
        assert!(s.take_hard(NodeId(2)), "hard flag readable once");
        assert!(!s.take_hard(NodeId(2)), "and consumed");
        // A node the injector never saw cannot 'come back'.
        assert!(!s.set_up(NodeId(99)));
        assert!(s.is_up(NodeId(99)));
    }
}
