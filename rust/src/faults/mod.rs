//! Fault injection & recovery: datanode crashes, re-replication,
//! stragglers, and speculative execution.
//!
//! The paper's efficiency numbers are measured on fault-free runs, but
//! the whole reason HDFS triples every written byte is failure
//! tolerance. This subsystem closes the loop: a seeded [`InjectionPlan`]
//! schedules crashes, CPU stragglers and disk degrades into the engine;
//! the HDFS layer reacts with dead-node detection, **write-pipeline
//! failover mid-block** and **block re-replication** from surviving
//! copies; the MapReduce layer reacts with TaskTracker blacklisting,
//! re-execution of lost map outputs, and Hadoop-0.20-style speculative
//! execution of straggling maps (progress-rate threshold, kill-loser).
//!
//! * [`plan`] — [`InjectionPlan`] → deterministic [`FaultSchedule`]
//!   (all sampling on a dedicated RNG stream keyed by the scenario's
//!   stable id, so faults are identical across thread counts and
//!   [`crate::sim::SolverMode`]s);
//! * [`injector`] — schedules the fault events as engine timers;
//! * [`recovery`] — crash orchestration: mark the node dead, run the
//!   registered protocol failover handlers, kill every remaining flow
//!   touching the node, and re-replicate under-replicated blocks.
//!
//! **Identity invariant:** with an empty plan nothing is installed — no
//! timers, no RNG draws, no extra state transitions — so fault-free
//! output (including `BENCH_sweep.json`) is byte-identical to a build
//! without this subsystem. `tests/integration_faults.rs` pins this.
//!
//! Modeling conventions (documented simplifications):
//!
//! * Crashed nodes never return; re-replication restores the replica
//!   count on the survivors (Hadoop's NameNode repair path).
//! * A v0.20 pipeline that loses a DataNode continues on the surviving
//!   replicas for the in-flight block (stock recovery semantics); the
//!   committed block is topped back up to the replication factor by an
//!   immediate re-replication transfer.
//! * Killed task attempts stop at their next phase boundary; flows
//!   already in flight on healthy nodes run out (their time is counted
//!   as wasted work), while flows touching the dead node are cancelled
//!   at the instant of the crash.

pub mod injector;
pub mod plan;
pub mod recovery;

pub use injector::install;
pub use plan::{
    fault_stream_seed, CrashSpec, FaultEvent, FaultKind, FaultSchedule, InjectionPlan,
    RackBrownoutSpec, RackCrashSpec,
};

use crate::cluster::NodeId;
use crate::sim::Engine;

/// A protocol-layer crash reaction (in-flight HDFS write/read failover,
/// job-scheduler blacklisting). Called once per crash with the dead
/// node; returning `false` deregisters the handler.
pub type FailoverHandler = Box<dyn FnMut(&mut Engine, NodeId) -> bool>;

/// Counters describing what the fault subsystem did to a run. Everything
/// here is deterministic for a given plan + stream seed.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultStats {
    /// Nodes that crashed.
    pub crashes: usize,
    /// Whole-rack failures processed (each also counts its member
    /// crashes in `crashes`).
    pub rack_crashes: usize,
    /// ToR-uplink brownouts applied.
    pub rack_brownouts: usize,
    /// Nodes slowed by a straggler event.
    pub stragglers: usize,
    /// Nodes whose data disk degraded.
    pub disk_degrades: usize,
    /// Block re-replication transfers started / completed.
    pub rereplications_started: usize,
    pub rereplications_done: usize,
    /// Bytes moved by re-replication (wire bytes, stored size).
    pub recovery_bytes: f64,
    /// Blocks that lost every replica (unrecoverable; counted once per
    /// block by the post-crash namespace scan).
    pub blocks_lost: usize,
    /// Read attempts that hit a lost block and skipped it (one per
    /// attempted read, so re-reads count again).
    pub lost_block_reads: usize,
    /// In-flight write pipelines rebuilt around a dead DataNode.
    pub pipeline_failovers: usize,
    /// In-flight reads re-pointed at a surviving replica.
    pub read_failovers: usize,
    /// Whole-file writes abandoned because the writing client died.
    pub writes_aborted: usize,
    /// Map / reduce attempts re-queued after a TaskTracker death.
    pub maps_requeued: usize,
    pub reduces_requeued: usize,
    /// Completed map outputs lost with their host and re-executed.
    pub map_outputs_lost: usize,
    /// Speculative map attempts launched / won / wasted.
    pub spec_launched: usize,
    pub spec_wins: usize,
    pub spec_wasted: usize,
    /// Simulated seconds of task work thrown away (killed attempts).
    pub wasted_task_seconds: f64,
}

/// Per-run fault state, owned by [`crate::hdfs::World`]. For fault-free
/// runs it stays inert: `active` is false, the handler list is empty,
/// and no code path consults anything else.
pub struct FaultState {
    /// Per-node liveness (index = node id). Empty until the injector
    /// installs a schedule; [`FaultState::is_up`] treats missing entries
    /// as up, so fault-free runs never allocate.
    node_up: Vec<bool>,
    /// True once a non-empty schedule was installed.
    pub active: bool,
    /// Speculative execution enabled (scheduler consults this).
    pub speculation: bool,
    /// Registered crash reactions, run in registration order.
    pub(crate) handlers: Vec<FailoverHandler>,
    pub stats: FaultStats,
}

impl Default for FaultState {
    fn default() -> Self {
        FaultState::new()
    }
}

impl FaultState {
    pub fn new() -> FaultState {
        FaultState {
            node_up: Vec::new(),
            active: false,
            speculation: false,
            handlers: Vec::new(),
            stats: FaultStats::default(),
        }
    }

    /// Arm the state for a cluster of `nodes` nodes (all up).
    pub(crate) fn arm(&mut self, nodes: usize, speculation: bool) {
        if self.node_up.len() < nodes {
            self.node_up.resize(nodes, true);
        }
        self.active = true;
        self.speculation = speculation;
    }

    /// Is `node` alive? Nodes never seen by the injector are always up.
    pub fn is_up(&self, node: NodeId) -> bool {
        self.node_up.get(node.0).copied().unwrap_or(true)
    }

    /// Mark `node` dead; returns false if it already was.
    pub(crate) fn set_down(&mut self, node: NodeId) -> bool {
        if self.node_up.len() <= node.0 {
            self.node_up.resize(node.0 + 1, true);
        }
        let was_up = self.node_up[node.0];
        self.node_up[node.0] = false;
        was_up
    }

    /// Register a crash reaction. Handlers self-deregister by returning
    /// false (e.g. when the protocol operation they guard has finished).
    pub fn register(&mut self, h: FailoverHandler) {
        self.handlers.push(h);
    }
}

/// Run every registered failover handler for a crash of `node`.
///
/// Handlers may borrow the world and may register *new* handlers while
/// running (a rebuilt pipeline re-arms its guard), so the list is taken
/// out of the world for the duration and merged back afterwards.
pub fn dispatch_crash(
    engine: &mut Engine,
    world: &crate::hdfs::WorldHandle,
    node: NodeId,
) {
    let mut handlers = std::mem::take(&mut world.borrow_mut().faults.handlers);
    let mut kept: Vec<FailoverHandler> = Vec::with_capacity(handlers.len());
    for mut h in handlers.drain(..) {
        if h(engine, node) {
            kept.push(h);
        }
    }
    let mut w = world.borrow_mut();
    // Handlers registered during dispatch landed in the (emptied) world
    // list; keep them after the surviving originals so registration
    // order stays chronological.
    let new = std::mem::take(&mut w.faults.handlers);
    w.faults.handlers = kept;
    w.faults.handlers.extend(new);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_state_is_inert() {
        let s = FaultState::new();
        assert!(!s.active);
        assert!(!s.speculation);
        assert!(s.is_up(NodeId(0)));
        assert!(s.is_up(NodeId(99)));
        assert_eq!(s.stats, FaultStats::default());
    }

    #[test]
    fn arm_and_down_tracking() {
        let mut s = FaultState::new();
        s.arm(4, true);
        assert!(s.active && s.speculation);
        assert!(s.is_up(NodeId(3)));
        assert!(s.set_down(NodeId(3)));
        assert!(!s.is_up(NodeId(3)));
        assert!(!s.set_down(NodeId(3)), "second down is a no-op");
        assert!(s.is_up(NodeId(1)));
    }
}
