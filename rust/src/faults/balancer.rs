//! The v0.20-style background **rack-aware balancer**.
//!
//! Hadoop's balancer is an administrative daemon that iteratively moves
//! block replicas from over- to under-utilized DataNodes until every
//! node's utilization sits within a threshold of the cluster average,
//! throttling each transfer to `dfs.balance.bandwidthPerSec` and never
//! reducing the number of racks a block spans. This module reproduces
//! that protocol as a periodic engine timer chain:
//!
//! * every [`BalancerConfig::interval_s`] seconds a **round** computes
//!   per-node stored bytes (in-flight moves counted as already applied),
//!   classifies nodes against the `mean × (1 ± threshold)` band the way
//!   Hadoop's balancer does (over-utilized / above-average /
//!   below-average / under-utilized), and pairs above-mean sources with
//!   below-mean targets whenever at least one side breaches the band —
//!   at most one move per source per round, each move strictly reducing
//!   the pair's combined deviation from the mean (so rounds can never
//!   oscillate);
//! * moves ride the same DataNode-to-DataNode transfer path as crash
//!   re-replication but carry `balance:*` usage classes, so their
//!   energy is attributed as
//!   [`crate::energy::EnergyReport::balance_joules`] — the steady-state
//!   price of churn, separate from crash-repair joules;
//! * after [`IDLE_ROUNDS_TO_PARK`] consecutive do-nothing rounds the
//!   chain **parks** (stops re-arming, letting the engine drain);
//!   crashes, drains, and recommissions `kick` it awake again — a
//!   freshly re-joined (near-empty) node is precisely what the next
//!   round refills.
//!
//! Determinism: rounds scan the namespace in sorted file order, node
//! sets sort by (bytes, id), and no RNG is consumed — a balanced run is
//! byte-identical across thread counts and
//! [`crate::sim::SolverMode`]s. Without a [`BalancerConfig`] installed
//! nothing here ever runs, preserving the empty-plan identity
//! invariant.

use crate::cluster::NodeId;
use crate::hdfs::{World, WorldHandle};
use crate::sim::Engine;

use super::plan::BalancerConfig;
use super::recovery;
use super::PendingMove;

/// Consecutive do-nothing rounds before the poll chain parks itself.
/// Three rounds ride out the startup window where the namespace is
/// still empty (a workload has not written anything yet).
pub const IDLE_ROUNDS_TO_PARK: usize = 3;

/// One planned replica move.
#[derive(Debug, Clone)]
struct Move {
    file: String,
    block_idx: usize,
    block_id: u64,
    bytes: f64,
    source: NodeId,
    target: NodeId,
}

/// Install the balancer for this run and schedule its first round.
/// Called by the injector when the fault schedule carries a
/// [`BalancerConfig`].
pub fn install(engine: &mut Engine, world: &WorldHandle, cfg: BalancerConfig) {
    let interval = cfg.interval_s.max(1e-3);
    {
        let mut w = world.borrow_mut();
        w.faults.balancer = Some(cfg);
        w.faults.balancer_running = true;
        w.faults.balancer_idle_rounds = 0;
    }
    let world2 = world.clone();
    engine.after(interval, move |e| poll(e, &world2));
}

/// Wake a parked balancer chain after a membership or namespace skew
/// change (crash, drain completion, recommission). No-op when no
/// balancer is installed or the chain is already running.
pub(crate) fn kick(engine: &mut Engine, world: &WorldHandle) {
    let interval = {
        let mut w = world.borrow_mut();
        let Some(cfg) = &w.faults.balancer else { return };
        let interval = cfg.interval_s.max(1e-3);
        w.faults.balancer_idle_rounds = 0;
        if w.faults.balancer_running {
            return;
        }
        w.faults.balancer_running = true;
        interval
    };
    let world2 = world.clone();
    engine.after(interval, move |e| poll(e, &world2));
}

/// One balancer round: plan moves, start them, re-arm (or park).
fn poll(engine: &mut Engine, world: &WorldHandle) {
    let (interval, moves) = {
        let w = world.borrow();
        let Some(cfg) = w.faults.balancer.clone() else { return };
        (cfg.interval_s.max(1e-3), plan_moves(&w, &cfg))
    };
    if moves.is_empty() {
        let mut w = world.borrow_mut();
        w.faults.balancer_idle_rounds += 1;
        if w.faults.balancer_idle_rounds >= IDLE_ROUNDS_TO_PARK {
            w.faults.balancer_running = false;
            return;
        }
    } else {
        {
            let mut w = world.borrow_mut();
            w.faults.balancer_idle_rounds = 0;
            w.faults.stats.balancer_rounds += 1;
        }
        if engine.trace_enabled() {
            engine.trace_instant("balance", format!("balancer round: {} moves", moves.len()), 0);
        }
        if engine.metrics_enabled() {
            engine.metric_incr("balance.rounds", 1);
        }
        let world2 = world.clone();
        engine.batch(move |engine| {
            for m in moves {
                start_move(engine, &world2, m);
            }
        });
    }
    let world3 = world.clone();
    engine.after(interval, move |e| poll(e, &world3));
}

/// Plan one round of moves over the current namespace. Pure read-only
/// analysis; deterministic (sorted scans, no RNG).
fn plan_moves(w: &World, cfg: &BalancerConfig) -> Vec<Move> {
    let eligible = w.namenode.target_datanodes();
    if eligible.len() < 2 {
        return Vec::new();
    }
    let mut bytes = w.namenode.stored_bytes();
    let max_id = eligible.iter().map(|n| n.0 + 1).max().unwrap_or(0);
    if bytes.len() < max_id {
        bytes.resize(max_id, 0.0);
    }
    // Count in-flight moves as already applied so consecutive rounds
    // never double-plan the same imbalance.
    for p in &w.faults.balancer_pending {
        if p.source.0 < bytes.len() {
            bytes[p.source.0] -= p.bytes;
        }
        if p.target.0 < bytes.len() {
            bytes[p.target.0] += p.bytes;
        }
    }
    let total: f64 = eligible.iter().map(|n| bytes[n.0]).sum();
    if total <= 0.0 {
        return Vec::new();
    }
    let mean = total / eligible.len() as f64;
    let hi = mean * (1.0 + cfg.threshold);
    let lo = mean * (1.0 - cfg.threshold);
    // Hadoop's four-way classification: a pair is workable when the
    // source is above the mean, the target below it, and at least one
    // of them breaches the threshold band (over → under, over →
    // below-average, above-average → under). Everyone inside the band
    // with no breacher on either side = balanced.
    if !eligible.iter().any(|n| bytes[n.0] > hi) && !eligible.iter().any(|n| bytes[n.0] < lo) {
        return Vec::new();
    }
    let mut sources: Vec<NodeId> = eligible.iter().copied().filter(|n| bytes[n.0] > mean).collect();
    let mut targets: Vec<NodeId> = eligible.iter().copied().filter(|n| bytes[n.0] < mean).collect();
    if sources.is_empty() || targets.is_empty() {
        return Vec::new();
    }
    // Most-over-utilized sources first, neediest targets first; ties by
    // node id so the plan is deterministic.
    sources.sort_by(|a, b| bytes[b.0].total_cmp(&bytes[a.0]).then(a.0.cmp(&b.0)));
    targets.sort_by(|a, b| bytes[a.0].total_cmp(&bytes[b.0]).then(a.0.cmp(&b.0)));
    // One sorted namespace scan shared by every pick in this round.
    let mut names: Vec<&str> = w.namenode.files().map(|(n, _)| n).collect();
    names.sort_unstable();
    let mut moves: Vec<Move> = Vec::new();
    let mut virt = bytes;
    'sources: for &src in &sources {
        if moves.len() >= cfg.max_moves_per_round.max(1) {
            break;
        }
        for &dst in &targets {
            if virt[src.0] <= mean || virt[dst.0] >= mean {
                continue; // drifted inside by an earlier pick this round
            }
            if virt[src.0] <= hi && virt[dst.0] >= lo {
                continue; // neither side breaches the band
            }
            if let Some(mv) = pick_move(w, &names, src, dst, &virt, mean, &moves) {
                virt[src.0] -= mv.bytes.max(1.0);
                virt[dst.0] += mv.bytes.max(1.0);
                moves.push(mv);
                continue 'sources;
            }
        }
    }
    moves
}

/// Choose the first block (sorted file order) on `src` that can legally
/// move to `dst`: the target must not already hold it, no in-flight or
/// same-round move may already touch it, the move must strictly shrink
/// the pair's combined deviation from the mean (so the cluster-wide
/// imbalance decreases monotonically — rounds can never oscillate), and
/// the block must keep spanning at least as many racks as before (the
/// v0.20 balancer's placement-policy preservation rule).
#[allow(clippy::too_many_arguments)]
fn pick_move(
    w: &World,
    names: &[&str],
    src: NodeId,
    dst: NodeId,
    virt: &[f64],
    mean: f64,
    planned: &[Move],
) -> Option<Move> {
    let src_dev = virt[src.0] - mean;
    let dst_dev = mean - virt[dst.0];
    if src_dev <= 0.0 || dst_dev <= 0.0 {
        return None;
    }
    for &name in names {
        let meta = w.namenode.get_file(name)?;
        for (i, b) in meta.blocks.iter().enumerate() {
            if !b.replicas.contains(&src) || b.replicas.contains(&dst) {
                continue;
            }
            if w.faults.balancer_pending.iter().any(|p| p.block_id == b.id)
                || w.faults.drain_pending.iter().any(|p| p.block_id == b.id)
                || planned.iter().any(|p| p.block_id == b.id)
            {
                continue;
            }
            let bsz = b.stored_size.max(1.0);
            // Combined-deviation improvement: |src−b−mean| + |dst+b−mean|
            // must be strictly smaller than the pair's deviation now.
            if (src_dev - bsz).abs() + (dst_dev - bsz).abs() >= src_dev + dst_dev {
                continue;
            }
            if !rack_spread_preserved(w, &b.replicas, src, dst) {
                continue;
            }
            return Some(Move {
                file: name.to_string(),
                block_idx: i,
                block_id: b.id,
                bytes: b.stored_size,
                source: src,
                target: dst,
            });
        }
    }
    None
}

/// Would moving one replica `src` → `dst` keep the block spanning at
/// least as many racks as it does now (the v0.20 balancer's rule: a
/// move never reduces the number of racks a block spans)? Trivially
/// true on the flat topology.
fn rack_spread_preserved(w: &World, replicas: &[NodeId], src: NodeId, dst: NodeId) -> bool {
    if w.cluster.racks() <= 1 {
        return true;
    }
    let distinct = |nodes: &mut dyn Iterator<Item = NodeId>| {
        let mut racks: Vec<usize> = nodes.map(|n| w.cluster.rack_of(n)).collect();
        racks.sort_unstable();
        racks.dedup();
        racks.len()
    };
    let before = distinct(&mut replicas.iter().copied());
    let after = distinct(
        &mut replicas.iter().copied().filter(|r| *r != src).chain(std::iter::once(dst)),
    );
    after >= before
}

/// Start one planned move: a bandwidth-capped `balance:*` transfer; on
/// completion the NameNode swaps the replica (target added, source
/// invalidated) — unless the target died mid-copy, in which case the
/// pending entry is simply dropped and a later round retries.
fn start_move(engine: &mut Engine, world: &WorldHandle, m: Move) {
    let Move { file, block_idx, block_id, bytes, source, target } = m;
    let cap = {
        let mut w = world.borrow_mut();
        w.faults.stats.balancer_moves_started += 1;
        w.faults.stats.balance_bytes += bytes.max(1.0);
        w.faults.balancer_pending.push(PendingMove {
            block_id,
            source,
            target,
            bytes: bytes.max(1.0),
        });
        w.faults.balancer.as_ref().map(|c| c.bandwidth_bps)
    };
    recovery::start_transfer(
        engine,
        world,
        source,
        target,
        bytes,
        "balance",
        cap,
        move |_engine, w| {
            w.faults.balancer_pending.retain(|p| p.block_id != block_id);
            // The target must still be a real destination: landing on a
            // node that died or started draining mid-copy would only
            // force the block to move again immediately.
            if w.faults.is_up(target)
                && w.namenode.is_placement_target(target)
                && w.namenode.move_replica(&file, block_idx, source, target)
            {
                w.faults.stats.balancer_moves_done += 1;
            }
        },
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::hdfs::{BlockMeta, FileMeta};
    use crate::hw::{amdahl_blade, DiskKind, MIB};
    use crate::sim::engine::shared;

    fn world_with_skew(n: usize, blocks_on: &[(usize, usize)]) -> (Engine, WorldHandle) {
        // blocks_on: (node, block_count) — every block 8 MiB, r = 1.
        let mut e = Engine::new(1);
        let cluster = Cluster::build(&mut e, &amdahl_blade(DiskKind::Raid0), n);
        let mut w = World::new(cluster);
        w.namenode.set_datanodes((1..n).map(NodeId).collect());
        let mut id = 0u64;
        for &(node, count) in blocks_on {
            for k in 0..count {
                id += 1;
                w.namenode.put_file(
                    &format!("f/n{node}-{k}"),
                    FileMeta {
                        blocks: vec![BlockMeta {
                            id,
                            size: 8.0 * MIB,
                            stored_size: 8.0 * MIB,
                            replicas: vec![NodeId(node)],
                        }],
                    },
                );
            }
        }
        (e, shared(w))
    }

    #[test]
    fn balancer_levels_a_skewed_cluster() {
        // Node 1 holds 9 blocks, nodes 2 and 3 are empty (9 blocks over
        // 3 nodes divide evenly, so the balancer can land exactly on
        // the mean).
        let (mut e, w) = world_with_skew(4, &[(1, 9)]);
        install(
            &mut e,
            &w,
            BalancerConfig { bandwidth_bps: 100.0 * MIB, ..BalancerConfig::default() },
        );
        e.run();
        let wb = w.borrow();
        let bytes = wb.namenode.stored_bytes();
        let mean = (bytes[1] + bytes[2] + bytes[3]) / 3.0;
        for n in 1..=3usize {
            assert!(
                bytes[n] <= mean * 1.11 && bytes[n] >= mean * 0.89,
                "node {n} at {:.0} vs mean {:.0} after balancing: {:?}",
                bytes[n],
                mean,
                wb.faults.stats
            );
        }
        assert!(wb.faults.stats.balancer_moves_done >= 4, "{:?}", wb.faults.stats);
        assert_eq!(
            wb.faults.stats.balancer_moves_started,
            wb.faults.stats.balancer_moves_done
        );
        assert!(wb.faults.balancer_pending.is_empty());
        assert!(!wb.faults.balancer_running, "chain must park when balanced");
    }

    #[test]
    fn balanced_cluster_parks_without_moving() {
        let (mut e, w) = world_with_skew(4, &[(1, 3), (2, 3), (3, 3)]);
        install(&mut e, &w, BalancerConfig::default());
        e.run();
        let wb = w.borrow();
        assert_eq!(wb.faults.stats.balancer_moves_started, 0);
        assert!(!wb.faults.balancer_running);
        // Parked after exactly IDLE_ROUNDS_TO_PARK polls.
        assert!((e.now() - 30.0).abs() < 1e-6, "parked at {}", e.now());
    }

    #[test]
    fn bandwidth_cap_throttles_moves() {
        // An 8 MiB move at 0.125 MiB/s outlives the parked poll chain,
        // so the slow run's makespan is the transfer, not the chain.
        let run = |bw: f64| {
            let (mut e, w) = world_with_skew(3, &[(1, 4)]);
            install(&mut e, &w, BalancerConfig { bandwidth_bps: bw, ..Default::default() });
            e.run();
            let moved = w.borrow().faults.stats.balancer_moves_done;
            (e.now(), moved)
        };
        let (slow_t, slow_moves) = run(0.125 * MIB);
        let (fast_t, fast_moves) = run(64.0 * MIB);
        assert!(slow_moves >= 1 && fast_moves >= 1);
        assert!(
            slow_t > fast_t,
            "0.125 MiB/s cap should finish later than 64 MiB/s ({slow_t:.1} vs {fast_t:.1})"
        );
    }

    #[test]
    fn rack_spread_rule() {
        let mut e = Engine::new(1);
        // 6 nodes, 2 racks: r0={0,1,2}, r1={3,4,5}.
        let cluster = Cluster::build_racked(&mut e, &amdahl_blade(DiskKind::Raid0), 6, 2, 1.0);
        let mut w = World::new(cluster);
        w.namenode.set_datanodes((1..6).map(NodeId).collect());
        let replicas = vec![NodeId(1), NodeId(3)];
        let w = shared(w);
        let wb = w.borrow();
        // Moving the rack-0 copy inside rack 0 keeps the spread...
        assert!(rack_spread_preserved(&wb, &replicas, NodeId(1), NodeId(2)));
        // ...moving it into rack 1 collapses the block into one rack.
        assert!(!rack_spread_preserved(&wb, &replicas, NodeId(1), NodeId(4)));
        // A single-replica block can go anywhere.
        assert!(rack_spread_preserved(&wb, &[NodeId(1)], NodeId(1), NodeId(4)));
    }
}
