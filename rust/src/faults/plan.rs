//! Fault injection plans and their expansion into concrete schedules.
//!
//! An [`InjectionPlan`] is declarative: fixed crash entries, an optional
//! per-node MTBF, straggler and disk-degrade distributions, the node
//! **lifecycle** entries (graceful decommissions, timed recommissions,
//! and the crash → re-join delay), the background [`BalancerConfig`],
//! and the speculative-execution switch. [`FaultSchedule::generate`]
//! expands it into a sorted list of timestamped [`FaultEvent`]s using a
//! dedicated RNG stream, so the *same plan + same stream seed* always
//! produces the same faults — independent of thread count, solver mode,
//! or the order scenarios were inserted into a sweep grid.

use crate::hw::MIB;
use crate::sim::Rng;

/// One fixed crash entry: node `node` dies at simulated time `at`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CrashSpec {
    /// Node index (must be a slave: the master never crashes — a master
    /// failure is a whole-job failure, out of scope for this model).
    pub node: usize,
    /// Simulated seconds after engine start.
    pub at: f64,
}

/// One fixed whole-rack failure: every node in `rack` (the master is
/// spared) plus the rack's ToR uplink dies at `at`. Meaningful only on
/// multi-rack topologies ([`crate::cluster::Cluster::build_racked`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RackCrashSpec {
    /// Rack index.
    pub rack: usize,
    /// Simulated seconds after engine start.
    pub at: f64,
}

/// One fixed ToR-uplink brownout: `rack`'s uplink capacity dips to
/// `factor` of nominal at `at` (both directions).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RackBrownoutSpec {
    /// Rack index.
    pub rack: usize,
    /// Simulated seconds after engine start.
    pub at: f64,
    /// Capacity multiplier in (0, 1].
    pub factor: f64,
}

/// One graceful decommission: node `node` enters the *decommissioning*
/// state at `at` — it stops receiving new replicas and tasks, drains
/// every block it holds onto other live DataNodes (sourced from itself,
/// the whole point of a graceful exit), and goes administratively dead
/// once the drain completes. Running task attempts are allowed to
/// finish; no flows are cancelled.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DecommissionSpec {
    /// Node index (must be a slave; the master never leaves).
    pub node: usize,
    /// Simulated seconds after engine start.
    pub at: f64,
}

/// One timed recommission: node `node` re-joins the cluster at `at`.
/// A dead node comes back with healthy hardware, sends its **block
/// report** (blocks still on its intact disk re-register; copies made
/// redundant by crash-time re-replication are invalidated), re-registers
/// its TaskTracker with the JobTracker, and becomes a placement /
/// balancer target again. Recommissioning a node that is still *up* and
/// decommissioning cancels the decommission (Hadoop's remove-from-
/// excludes semantics); recommissioning a healthy node is a no-op.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RecommissionSpec {
    /// Node index.
    pub node: usize,
    /// Simulated seconds after engine start.
    pub at: f64,
}

/// Configuration of the v0.20-style background **rack-aware balancer**:
/// a periodic protocol that moves block replicas from over- to
/// under-utilized DataNodes until every node's stored bytes sit within
/// `threshold` of the cluster mean, never reducing the number of racks
/// a block spans, with each transfer capped at `bandwidth_bps` (the
/// `dfs.balance.bandwidthPerSec` knob). Balancer traffic carries
/// `balance:*` usage classes so its energy is attributed as
/// [`crate::energy::EnergyReport::balance_joules`].
#[derive(Debug, Clone, PartialEq)]
pub struct BalancerConfig {
    /// Allowed utilization band as a fraction of the cluster-mean
    /// stored bytes (Hadoop's balancer threshold; 0.1 = ±10%).
    pub threshold: f64,
    /// Per-transfer rate cap in bytes/s (`dfs.balance.bandwidthPerSec`;
    /// Hadoop's default is 1 MB/s — rebalancing is deliberately gentle).
    pub bandwidth_bps: f64,
    /// Seconds between balancer iterations.
    pub interval_s: f64,
    /// Moves started per iteration at most (one per over-utilized node).
    pub max_moves_per_round: usize,
}

impl Default for BalancerConfig {
    fn default() -> Self {
        BalancerConfig {
            threshold: 0.1,
            bandwidth_bps: 1.0 * MIB,
            interval_s: 10.0,
            max_moves_per_round: 4,
        }
    }
}

/// Declarative fault-injection plan. The default plan is **empty**: no
/// events are generated, no timers are scheduled, and simulation output
/// is byte-identical to a build without the subsystem.
///
/// Plans are built with struct-update syntax over [`InjectionPlan::empty`]:
///
/// ```
/// use amdahl_hadoop::faults::{BalancerConfig, CrashSpec, InjectionPlan};
///
/// // Node 3 crashes 10 s in, re-joins 60 s after the crash, and the
/// // background balancer refills it within a ±10% utilization band.
/// let plan = InjectionPlan {
///     crashes: vec![CrashSpec { node: 3, at: 10.0 }],
///     rejoin_after_s: Some(60.0),
///     balancer: Some(BalancerConfig::default()),
///     ..InjectionPlan::empty()
/// };
/// assert!(!plan.is_empty() && plan.active());
///
/// // The identity plan installs nothing at all.
/// assert!(InjectionPlan::empty().is_empty());
/// assert!(!InjectionPlan::empty().active());
/// ```
#[derive(Debug, Clone)]
pub struct InjectionPlan {
    /// Fixed crash schedule (applied verbatim, before MTBF sampling).
    pub crashes: Vec<CrashSpec>,
    /// Fixed whole-rack failures (every member node + the ToR uplink at
    /// once; ignored on flat single-rack topologies).
    pub rack_crashes: Vec<RackCrashSpec>,
    /// Fixed ToR-uplink brownouts.
    pub rack_brownouts: Vec<RackBrownoutSpec>,
    /// Fixed graceful decommissions (decommission → drain → dead).
    pub decommissions: Vec<DecommissionSpec>,
    /// Fixed recommissions (dead nodes re-joining at a set time; also
    /// cancels an in-progress decommission of a still-live node).
    pub recommissions: Vec<RecommissionSpec>,
    /// When set, every scheduled death — fixed or MTBF-sampled crashes,
    /// whole-rack failures, decommissions — is followed by a
    /// recommission of the same node (or rack) this many seconds later:
    /// the churn axis (`sweep --rejoin`).
    pub rejoin_after_s: Option<f64>,
    /// Background rack-aware balancer; None = not installed. A plan
    /// with only a balancer is *active* (timers run) but generates no
    /// fault events.
    pub balancer: Option<BalancerConfig>,
    /// Mean time between failures per slave node, seconds. When set,
    /// each slave's first crash time is sampled exponentially; crashes
    /// landing inside `crash_horizon_s` become events, earliest-first,
    /// capped at `max_crashes`.
    pub mtbf_s: Option<f64>,
    /// Cap on MTBF-sampled crashes (default 2: with `dfs.replication`
    /// 3, two dead nodes can never lose a block outright).
    pub max_crashes: usize,
    /// Sampling window for MTBF crashes, seconds.
    pub crash_horizon_s: f64,
    /// Fraction of slave nodes that become stragglers.
    pub straggler_frac: f64,
    /// CPU capacity multiplier applied to a straggler (0 < f < 1).
    pub straggler_slowdown: f64,
    /// Uniform window for straggler onset times, seconds.
    pub straggler_onset_s: (f64, f64),
    /// Fraction of slave nodes whose data disk degrades.
    pub disk_degrade_frac: f64,
    /// Disk throughput multiplier applied to a degraded disk.
    pub disk_degrade_factor: f64,
    /// Uniform window for disk-degrade onset times, seconds.
    pub disk_degrade_onset_s: (f64, f64),
    /// Hadoop-0.20-style speculative execution of straggling map tasks.
    pub speculation: bool,
}

impl Default for InjectionPlan {
    fn default() -> Self {
        InjectionPlan {
            crashes: Vec::new(),
            rack_crashes: Vec::new(),
            rack_brownouts: Vec::new(),
            decommissions: Vec::new(),
            recommissions: Vec::new(),
            rejoin_after_s: None,
            balancer: None,
            mtbf_s: None,
            max_crashes: 2,
            crash_horizon_s: 600.0,
            straggler_frac: 0.0,
            straggler_slowdown: 0.4,
            straggler_onset_s: (5.0, 50.0),
            disk_degrade_frac: 0.0,
            disk_degrade_factor: 0.3,
            disk_degrade_onset_s: (5.0, 50.0),
            speculation: false,
        }
    }
}

impl InjectionPlan {
    /// The identity plan: injects nothing.
    pub fn empty() -> InjectionPlan {
        InjectionPlan::default()
    }

    /// True when the plan generates no fault events at all.
    /// (`rejoin_after_s` alone does not count: with nothing scheduled
    /// to die, there is nothing to re-join.)
    pub fn is_empty(&self) -> bool {
        self.crashes.is_empty()
            && self.rack_crashes.is_empty()
            && self.rack_brownouts.is_empty()
            && self.decommissions.is_empty()
            && self.recommissions.is_empty()
            && self.mtbf_s.is_none()
            && self.straggler_frac <= 0.0
            && self.disk_degrade_frac <= 0.0
    }

    /// Should this plan be installed at all? Speculation counts:
    /// Hadoop hedges naturally slow maps on healthy clusters too, so
    /// `speculation: true` with no fault events is still a distinct,
    /// meaningful scenario (the scheduler's poll runs). The balancer
    /// counts for the same reason — steady-state rebalance traffic
    /// needs no fault to exist. Only an inert plan (`!active()`)
    /// preserves the byte-identity invariant.
    pub fn active(&self) -> bool {
        !self.is_empty() || self.speculation || self.balancer.is_some()
    }
}

/// One concrete scheduled fault.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// DataNode/TaskTracker process death (the node never returns).
    Crash,
    /// Whole-rack failure: every node in the rack (master spared) plus
    /// the ToR uplink at once. The event's `node` field carries the
    /// **rack index**.
    RackCrash,
    /// CPU slowdown to `factor` of nominal capacity.
    Straggle { factor: f64 },
    /// Data-disk throughput drop to `factor` of nominal.
    DiskDegrade { factor: f64 },
    /// ToR-uplink capacity dip to `factor` of nominal. The event's
    /// `node` field carries the **rack index**.
    RackBrownout { factor: f64 },
    /// Graceful decommission: the node drains its blocks, then goes
    /// administratively dead (no flows are cancelled).
    Decommission,
    /// A dead node re-joins (or an in-progress decommission is
    /// cancelled): block report, TaskTracker re-registration, resources
    /// re-armed.
    Recommission,
    /// Every dead member of a crashed rack re-joins, and the rack's ToR
    /// uplink is repaired. The event's `node` field carries the **rack
    /// index**.
    RackRecommission,
}

/// A timestamped fault on one node.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    /// Simulated seconds after engine start.
    pub at: f64,
    /// Node index (rack index for the rack-scoped kinds).
    pub node: usize,
    /// What happens.
    pub kind: FaultKind,
}

/// An expanded, sorted fault schedule plus the run-scoped switches that
/// ride along with it (speculation, the background balancer).
#[derive(Debug, Clone, Default)]
pub struct FaultSchedule {
    /// Timestamped fault events, sorted by time / node / kind.
    pub events: Vec<FaultEvent>,
    /// Speculative execution of straggling maps.
    pub speculation: bool,
    /// Background rack-aware balancer (None = not installed).
    pub balancer: Option<BalancerConfig>,
}

impl FaultSchedule {
    /// Expand `plan` for a cluster of `nodes` total nodes (node 0 is the
    /// master and never faults). All randomness comes from `stream_seed`
    /// — use [`fault_stream_seed`] to derive it from a scenario's stable
    /// id so sweep results do not depend on scenario insertion order.
    pub fn generate(plan: &InjectionPlan, stream_seed: u64, nodes: usize) -> FaultSchedule {
        let mut events = Vec::new();
        if plan.is_empty() || nodes < 2 {
            return FaultSchedule {
                events,
                speculation: plan.speculation,
                balancer: plan.balancer.clone(),
            };
        }
        let mut rng = Rng::new(stream_seed);
        let slaves: Vec<usize> = (1..nodes).collect();

        // Fixed crashes, verbatim (clamped to slave nodes).
        for c in &plan.crashes {
            if c.node >= 1 && c.node < nodes {
                events.push(FaultEvent { at: c.at.max(0.0), node: c.node, kind: FaultKind::Crash });
            }
        }

        // Fixed lifecycle entries, verbatim (clamped to slave nodes).
        for d in &plan.decommissions {
            if d.node >= 1 && d.node < nodes {
                events.push(FaultEvent {
                    at: d.at.max(0.0),
                    node: d.node,
                    kind: FaultKind::Decommission,
                });
            }
        }
        for r in &plan.recommissions {
            if r.node >= 1 && r.node < nodes {
                events.push(FaultEvent {
                    at: r.at.max(0.0),
                    node: r.node,
                    kind: FaultKind::Recommission,
                });
            }
        }

        // Whole-rack events, verbatim: the `node` field carries the rack
        // index; rack validity is checked at handle time against the
        // actual topology (the schedule does not know the rack count).
        for c in &plan.rack_crashes {
            events.push(FaultEvent { at: c.at.max(0.0), node: c.rack, kind: FaultKind::RackCrash });
        }
        for b in &plan.rack_brownouts {
            events.push(FaultEvent {
                at: b.at.max(0.0),
                node: b.rack,
                kind: FaultKind::RackBrownout { factor: b.factor.clamp(0.01, 1.0) },
            });
        }

        // MTBF-sampled crashes: one exponential draw per slave, in node
        // order (fixed draw order keeps the stream deterministic), then
        // keep the earliest `max_crashes` inside the horizon. The budget
        // counts only the fixed entries that survived validation, not
        // dropped ones (master / out-of-range nodes).
        if let Some(mtbf) = plan.mtbf_s {
            if mtbf > 0.0 {
                let mut cand: Vec<(f64, usize)> = Vec::new();
                for &n in &slaves {
                    let t = rng.exp(mtbf);
                    if t < plan.crash_horizon_s {
                        cand.push((t, n));
                    }
                }
                // Nodes already crash-scheduled by fixed entries must
                // not consume budget slots (a dropped duplicate would
                // silently under-inject).
                cand.retain(|&(_, n)| {
                    !events.iter().any(|e| e.node == n && e.kind == FaultKind::Crash)
                });
                cand.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
                let fixed = events.iter().filter(|e| e.kind == FaultKind::Crash).count();
                let budget = plan.max_crashes.saturating_sub(fixed);
                for &(t, n) in cand.iter().take(budget) {
                    events.push(FaultEvent { at: t, node: n, kind: FaultKind::Crash });
                }
            }
        }

        // Stragglers: shuffle the slave list, slow the first k.
        if plan.straggler_frac > 0.0 {
            let k = ((plan.straggler_frac * slaves.len() as f64).round() as usize)
                .clamp(1, slaves.len());
            let mut pool = slaves.clone();
            rng.shuffle(&mut pool);
            let (lo, hi) = plan.straggler_onset_s;
            for &n in pool.iter().take(k) {
                let at = rng.range(lo, hi.max(lo + 1e-9));
                events.push(FaultEvent {
                    at,
                    node: n,
                    kind: FaultKind::Straggle { factor: plan.straggler_slowdown },
                });
            }
        }

        // Disk degrades: same shape as stragglers, independent draw.
        if plan.disk_degrade_frac > 0.0 {
            let k = ((plan.disk_degrade_frac * slaves.len() as f64).round() as usize)
                .clamp(1, slaves.len());
            let mut pool = slaves.clone();
            rng.shuffle(&mut pool);
            let (lo, hi) = plan.disk_degrade_onset_s;
            for &n in pool.iter().take(k) {
                let at = rng.range(lo, hi.max(lo + 1e-9));
                events.push(FaultEvent {
                    at,
                    node: n,
                    kind: FaultKind::DiskDegrade { factor: plan.disk_degrade_factor },
                });
            }
        }

        // Deterministic order: by time, then node, then kind rank.
        events.sort_by(schedule_order);
        // Never kill the whole slave set: a dead cluster can neither
        // place replicas nor finish a job (the engine would panic or
        // idle forever). Keep the earliest `slaves - 1` scheduled
        // deaths — crashes *and* decommissions both remove a node, so
        // both consume cap slots — and at most one death per node; drop
        // the rest, fixed schedules included. (Whole-rack crashes are
        // capped at handle time instead, where the real member set is
        // known.)
        let death_cap = slaves.len().saturating_sub(1);
        let mut dying: Vec<usize> = Vec::new();
        events.retain(|e| {
            if !matches!(e.kind, FaultKind::Crash | FaultKind::Decommission) {
                return true;
            }
            if dying.len() < death_cap && !dying.contains(&e.node) {
                dying.push(e.node);
                true
            } else {
                false
            }
        });
        // Churn: every scheduled death that survived validation is
        // followed by a re-join `rejoin_after_s` later. Derived after
        // the crash cap so a dropped crash never spawns a phantom
        // recommission.
        if let Some(d) = plan.rejoin_after_s {
            if d >= 0.0 {
                let mut rejoins = Vec::new();
                for e in &events {
                    let kind = match e.kind {
                        FaultKind::Crash | FaultKind::Decommission => FaultKind::Recommission,
                        FaultKind::RackCrash => FaultKind::RackRecommission,
                        _ => continue,
                    };
                    rejoins.push(FaultEvent { at: e.at + d, node: e.node, kind });
                }
                events.extend(rejoins);
                events.sort_by(schedule_order);
            }
        }
        FaultSchedule { events, speculation: plan.speculation, balancer: plan.balancer.clone() }
    }
}

fn schedule_order(a: &FaultEvent, b: &FaultEvent) -> std::cmp::Ordering {
    a.at.total_cmp(&b.at).then(a.node.cmp(&b.node)).then(kind_rank(a.kind).cmp(&kind_rank(b.kind)))
}

fn kind_rank(k: FaultKind) -> u8 {
    match k {
        FaultKind::Crash => 0,
        FaultKind::RackCrash => 1,
        FaultKind::Straggle { .. } => 2,
        FaultKind::DiskDegrade { .. } => 3,
        FaultKind::RackBrownout { .. } => 4,
        // A death always precedes a same-instant re-join of the same
        // node, so a zero-delay churn cycle still round-trips.
        FaultKind::Decommission => 5,
        FaultKind::Recommission => 6,
        FaultKind::RackRecommission => 7,
    }
}

/// Derive the fault-event RNG stream seed from a scenario's **stable id**
/// (never from insertion order): the same scenario gets the same faults
/// under any `--threads` value and any grid reshape.
pub fn fault_stream_seed(scenario_seed: u64, scenario_id: &str) -> u64 {
    crate::sweep::grid::derive_seed(scenario_seed ^ 0xFA17_FA17_FA17_FA17, scenario_id)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_generates_nothing() {
        let p = InjectionPlan::empty();
        assert!(p.is_empty());
        let s = FaultSchedule::generate(&p, 7, 9);
        assert!(s.events.is_empty());
        assert!(!s.speculation);
    }

    #[test]
    fn fixed_crashes_pass_through() {
        let p = InjectionPlan {
            crashes: vec![CrashSpec { node: 3, at: 12.0 }, CrashSpec { node: 0, at: 1.0 }],
            ..InjectionPlan::empty()
        };
        assert!(!p.is_empty());
        let s = FaultSchedule::generate(&p, 7, 9);
        // The master entry is dropped; the slave crash survives.
        assert_eq!(s.events.len(), 1);
        assert_eq!(s.events[0].node, 3);
        assert_eq!(s.events[0].kind, FaultKind::Crash);
    }

    #[test]
    fn mtbf_sampling_is_deterministic_and_capped() {
        let p = InjectionPlan {
            mtbf_s: Some(100.0),
            max_crashes: 2,
            crash_horizon_s: 1e9,
            ..InjectionPlan::empty()
        };
        let a = FaultSchedule::generate(&p, 42, 9);
        let b = FaultSchedule::generate(&p, 42, 9);
        assert_eq!(a.events, b.events);
        assert!(a.events.len() <= 2);
        assert!(!a.events.is_empty());
        for w in a.events.windows(2) {
            assert!(w[0].at <= w[1].at, "events must be time-sorted");
        }
        // A different stream seed moves the schedule.
        let c = FaultSchedule::generate(&p, 43, 9);
        assert_ne!(a.events, c.events);
    }

    #[test]
    fn stragglers_sampled_from_slaves_only() {
        let p = InjectionPlan { straggler_frac: 0.5, ..InjectionPlan::empty() };
        let s = FaultSchedule::generate(&p, 5, 9);
        assert_eq!(s.events.len(), 4); // round(0.5 * 8)
        for e in &s.events {
            assert!(e.node >= 1 && e.node < 9);
            assert!(matches!(e.kind, FaultKind::Straggle { .. }));
            assert!(e.at >= 5.0 && e.at < 50.0);
        }
        // All distinct nodes.
        let mut nodes: Vec<usize> = s.events.iter().map(|e| e.node).collect();
        nodes.sort_unstable();
        nodes.dedup();
        assert_eq!(nodes.len(), 4);
    }

    #[test]
    fn rack_events_pass_through_sorted() {
        let p = InjectionPlan {
            rack_crashes: vec![RackCrashSpec { rack: 2, at: 30.0 }],
            rack_brownouts: vec![RackBrownoutSpec { rack: 1, at: 5.0, factor: 0.25 }],
            ..InjectionPlan::empty()
        };
        assert!(!p.is_empty() && p.active());
        let s = FaultSchedule::generate(&p, 11, 9);
        assert_eq!(s.events.len(), 2);
        assert_eq!(s.events[0].node, 1);
        assert_eq!(s.events[0].kind, FaultKind::RackBrownout { factor: 0.25 });
        assert_eq!(s.events[1].node, 2);
        assert_eq!(s.events[1].kind, FaultKind::RackCrash);
    }

    #[test]
    fn rejoin_delay_schedules_recommissions_after_each_death() {
        let p = InjectionPlan {
            crashes: vec![CrashSpec { node: 2, at: 5.0 }],
            decommissions: vec![DecommissionSpec { node: 3, at: 8.0 }],
            rack_crashes: vec![RackCrashSpec { rack: 1, at: 10.0 }],
            rejoin_after_s: Some(20.0),
            ..InjectionPlan::empty()
        };
        let s = FaultSchedule::generate(&p, 3, 9);
        assert_eq!(s.events.len(), 6, "{:?}", s.events);
        let rejoins: Vec<&FaultEvent> = s
            .events
            .iter()
            .filter(|e| {
                matches!(e.kind, FaultKind::Recommission | FaultKind::RackRecommission)
            })
            .collect();
        assert_eq!(rejoins.len(), 3);
        assert!(rejoins.iter().any(|e| e.node == 2 && (e.at - 25.0).abs() < 1e-9));
        assert!(rejoins.iter().any(|e| e.node == 3 && (e.at - 28.0).abs() < 1e-9));
        assert!(rejoins.iter().any(|e| {
            e.node == 1 && (e.at - 30.0).abs() < 1e-9 && e.kind == FaultKind::RackRecommission
        }));
        for w in s.events.windows(2) {
            assert!(w[0].at <= w[1].at, "events must stay time-sorted");
        }
    }

    #[test]
    fn fixed_lifecycle_entries_clamp_to_slaves() {
        let p = InjectionPlan {
            decommissions: vec![
                DecommissionSpec { node: 0, at: 1.0 },
                DecommissionSpec { node: 4, at: 2.0 },
            ],
            recommissions: vec![
                RecommissionSpec { node: 99, at: 3.0 },
                RecommissionSpec { node: 4, at: 9.0 },
            ],
            ..InjectionPlan::empty()
        };
        assert!(!p.is_empty() && p.active());
        let s = FaultSchedule::generate(&p, 1, 9);
        assert_eq!(s.events.len(), 2, "{:?}", s.events);
        assert_eq!(s.events[0].kind, FaultKind::Decommission);
        assert_eq!(s.events[0].node, 4);
        assert_eq!(s.events[1].kind, FaultKind::Recommission);
        assert_eq!(s.events[1].node, 4);
    }

    /// Regression: the whole-slave-set survival cap must count
    /// decommissions as deaths too — a drain plus enough crashes could
    /// otherwise kill every slave (leaving placement to panic).
    #[test]
    fn death_cap_counts_decommissions_and_crashes_together() {
        let p = InjectionPlan {
            decommissions: vec![DecommissionSpec { node: 3, at: 1.0 }],
            crashes: vec![CrashSpec { node: 1, at: 2.0 }, CrashSpec { node: 2, at: 3.0 }],
            ..InjectionPlan::empty()
        };
        // 4 nodes = 3 slaves → at most 2 scheduled deaths survive.
        let s = FaultSchedule::generate(&p, 3, 4);
        let deaths = s
            .events
            .iter()
            .filter(|e| matches!(e.kind, FaultKind::Crash | FaultKind::Decommission))
            .count();
        assert_eq!(deaths, 2, "{:?}", s.events);
        // Earliest-first: the decommission (t=1) and the first crash
        // (t=2) survive; the crash that would empty the cluster drops.
        assert!(s.events.iter().any(|e| e.kind == FaultKind::Decommission && e.node == 3));
        assert!(s.events.iter().any(|e| e.kind == FaultKind::Crash && e.node == 1));
        assert!(!s.events.iter().any(|e| e.node == 2));
    }

    #[test]
    fn balancer_only_plan_is_active_but_eventless() {
        let p = InjectionPlan { balancer: Some(BalancerConfig::default()), ..InjectionPlan::empty() };
        assert!(p.is_empty(), "a balancer is not a fault event");
        assert!(p.active(), "but the protocol must install");
        let s = FaultSchedule::generate(&p, 1, 9);
        assert!(s.events.is_empty());
        assert_eq!(s.balancer, Some(BalancerConfig::default()));
    }

    #[test]
    fn fault_stream_seed_is_a_pure_function_of_the_id() {
        let a = fault_stream_seed(1, "amdahl-n9-c4-direct-nolzo-search-mtbf600");
        let b = fault_stream_seed(1, "amdahl-n9-c4-direct-nolzo-search-mtbf600");
        let c = fault_stream_seed(1, "amdahl-n9-c2-direct-nolzo-search-mtbf600");
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_ne!(fault_stream_seed(2, "x"), fault_stream_seed(1, "x"));
    }
}
