//! Fault injection plans and their expansion into concrete schedules.
//!
//! An [`InjectionPlan`] is declarative: fixed crash entries, an optional
//! per-node MTBF, straggler and disk-degrade distributions, and the
//! speculative-execution switch. [`FaultSchedule::generate`] expands it
//! into a sorted list of timestamped [`FaultEvent`]s using a dedicated
//! RNG stream, so the *same plan + same stream seed* always produces the
//! same faults — independent of thread count, solver mode, or the order
//! scenarios were inserted into a sweep grid.

use crate::sim::Rng;

/// One fixed crash entry: node `node` dies at simulated time `at`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CrashSpec {
    /// Node index (must be a slave: the master never crashes — a master
    /// failure is a whole-job failure, out of scope for this model).
    pub node: usize,
    /// Simulated seconds after engine start.
    pub at: f64,
}

/// One fixed whole-rack failure: every node in `rack` (the master is
/// spared) plus the rack's ToR uplink dies at `at`. Meaningful only on
/// multi-rack topologies ([`crate::cluster::Cluster::build_racked`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RackCrashSpec {
    /// Rack index.
    pub rack: usize,
    /// Simulated seconds after engine start.
    pub at: f64,
}

/// One fixed ToR-uplink brownout: `rack`'s uplink capacity dips to
/// `factor` of nominal at `at` (both directions).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RackBrownoutSpec {
    /// Rack index.
    pub rack: usize,
    /// Simulated seconds after engine start.
    pub at: f64,
    /// Capacity multiplier in (0, 1].
    pub factor: f64,
}

/// Declarative fault-injection plan. The default plan is **empty**: no
/// events are generated, no timers are scheduled, and simulation output
/// is byte-identical to a build without the subsystem.
#[derive(Debug, Clone)]
pub struct InjectionPlan {
    /// Fixed crash schedule (applied verbatim, before MTBF sampling).
    pub crashes: Vec<CrashSpec>,
    /// Fixed whole-rack failures (every member node + the ToR uplink at
    /// once; ignored on flat single-rack topologies).
    pub rack_crashes: Vec<RackCrashSpec>,
    /// Fixed ToR-uplink brownouts.
    pub rack_brownouts: Vec<RackBrownoutSpec>,
    /// Mean time between failures per slave node, seconds. When set,
    /// each slave's first crash time is sampled exponentially; crashes
    /// landing inside `crash_horizon_s` become events, earliest-first,
    /// capped at `max_crashes`.
    pub mtbf_s: Option<f64>,
    /// Cap on MTBF-sampled crashes (default 2: with `dfs.replication`
    /// 3, two dead nodes can never lose a block outright).
    pub max_crashes: usize,
    /// Sampling window for MTBF crashes, seconds.
    pub crash_horizon_s: f64,
    /// Fraction of slave nodes that become stragglers.
    pub straggler_frac: f64,
    /// CPU capacity multiplier applied to a straggler (0 < f < 1).
    pub straggler_slowdown: f64,
    /// Uniform window for straggler onset times, seconds.
    pub straggler_onset_s: (f64, f64),
    /// Fraction of slave nodes whose data disk degrades.
    pub disk_degrade_frac: f64,
    /// Disk throughput multiplier applied to a degraded disk.
    pub disk_degrade_factor: f64,
    /// Uniform window for disk-degrade onset times, seconds.
    pub disk_degrade_onset_s: (f64, f64),
    /// Hadoop-0.20-style speculative execution of straggling map tasks.
    pub speculation: bool,
}

impl Default for InjectionPlan {
    fn default() -> Self {
        InjectionPlan {
            crashes: Vec::new(),
            rack_crashes: Vec::new(),
            rack_brownouts: Vec::new(),
            mtbf_s: None,
            max_crashes: 2,
            crash_horizon_s: 600.0,
            straggler_frac: 0.0,
            straggler_slowdown: 0.4,
            straggler_onset_s: (5.0, 50.0),
            disk_degrade_frac: 0.0,
            disk_degrade_factor: 0.3,
            disk_degrade_onset_s: (5.0, 50.0),
            speculation: false,
        }
    }
}

impl InjectionPlan {
    /// The identity plan: injects nothing.
    pub fn empty() -> InjectionPlan {
        InjectionPlan::default()
    }

    /// True when the plan generates no fault events at all.
    pub fn is_empty(&self) -> bool {
        self.crashes.is_empty()
            && self.rack_crashes.is_empty()
            && self.rack_brownouts.is_empty()
            && self.mtbf_s.is_none()
            && self.straggler_frac <= 0.0
            && self.disk_degrade_frac <= 0.0
    }

    /// Should this plan be installed at all? Speculation counts:
    /// Hadoop hedges naturally slow maps on healthy clusters too, so
    /// `speculation: true` with no fault events is still a distinct,
    /// meaningful scenario (the scheduler's poll runs). Only an inert
    /// plan (`!active()`) preserves the byte-identity invariant.
    pub fn active(&self) -> bool {
        !self.is_empty() || self.speculation
    }
}

/// One concrete scheduled fault.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// DataNode/TaskTracker process death (the node never returns).
    Crash,
    /// Whole-rack failure: every node in the rack (master spared) plus
    /// the ToR uplink at once. The event's `node` field carries the
    /// **rack index**.
    RackCrash,
    /// CPU slowdown to `factor` of nominal capacity.
    Straggle { factor: f64 },
    /// Data-disk throughput drop to `factor` of nominal.
    DiskDegrade { factor: f64 },
    /// ToR-uplink capacity dip to `factor` of nominal. The event's
    /// `node` field carries the **rack index**.
    RackBrownout { factor: f64 },
}

/// A timestamped fault on one node.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    pub at: f64,
    pub node: usize,
    pub kind: FaultKind,
}

/// An expanded, sorted fault schedule plus the speculation switch.
#[derive(Debug, Clone, Default)]
pub struct FaultSchedule {
    pub events: Vec<FaultEvent>,
    pub speculation: bool,
}

impl FaultSchedule {
    /// Expand `plan` for a cluster of `nodes` total nodes (node 0 is the
    /// master and never faults). All randomness comes from `stream_seed`
    /// — use [`fault_stream_seed`] to derive it from a scenario's stable
    /// id so sweep results do not depend on scenario insertion order.
    pub fn generate(plan: &InjectionPlan, stream_seed: u64, nodes: usize) -> FaultSchedule {
        let mut events = Vec::new();
        if plan.is_empty() || nodes < 2 {
            return FaultSchedule { events, speculation: plan.speculation };
        }
        let mut rng = Rng::new(stream_seed);
        let slaves: Vec<usize> = (1..nodes).collect();

        // Fixed crashes, verbatim (clamped to slave nodes).
        for c in &plan.crashes {
            if c.node >= 1 && c.node < nodes {
                events.push(FaultEvent { at: c.at.max(0.0), node: c.node, kind: FaultKind::Crash });
            }
        }

        // Whole-rack events, verbatim: the `node` field carries the rack
        // index; rack validity is checked at handle time against the
        // actual topology (the schedule does not know the rack count).
        for c in &plan.rack_crashes {
            events.push(FaultEvent { at: c.at.max(0.0), node: c.rack, kind: FaultKind::RackCrash });
        }
        for b in &plan.rack_brownouts {
            events.push(FaultEvent {
                at: b.at.max(0.0),
                node: b.rack,
                kind: FaultKind::RackBrownout { factor: b.factor.clamp(0.01, 1.0) },
            });
        }

        // MTBF-sampled crashes: one exponential draw per slave, in node
        // order (fixed draw order keeps the stream deterministic), then
        // keep the earliest `max_crashes` inside the horizon. The budget
        // counts only the fixed entries that survived validation, not
        // dropped ones (master / out-of-range nodes).
        if let Some(mtbf) = plan.mtbf_s {
            if mtbf > 0.0 {
                let mut cand: Vec<(f64, usize)> = Vec::new();
                for &n in &slaves {
                    let t = rng.exp(mtbf);
                    if t < plan.crash_horizon_s {
                        cand.push((t, n));
                    }
                }
                // Nodes already crash-scheduled by fixed entries must
                // not consume budget slots (a dropped duplicate would
                // silently under-inject).
                cand.retain(|&(_, n)| {
                    !events.iter().any(|e| e.node == n && e.kind == FaultKind::Crash)
                });
                cand.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
                let fixed = events.iter().filter(|e| e.kind == FaultKind::Crash).count();
                let budget = plan.max_crashes.saturating_sub(fixed);
                for &(t, n) in cand.iter().take(budget) {
                    events.push(FaultEvent { at: t, node: n, kind: FaultKind::Crash });
                }
            }
        }

        // Stragglers: shuffle the slave list, slow the first k.
        if plan.straggler_frac > 0.0 {
            let k = ((plan.straggler_frac * slaves.len() as f64).round() as usize)
                .clamp(1, slaves.len());
            let mut pool = slaves.clone();
            rng.shuffle(&mut pool);
            let (lo, hi) = plan.straggler_onset_s;
            for &n in pool.iter().take(k) {
                let at = rng.range(lo, hi.max(lo + 1e-9));
                events.push(FaultEvent {
                    at,
                    node: n,
                    kind: FaultKind::Straggle { factor: plan.straggler_slowdown },
                });
            }
        }

        // Disk degrades: same shape as stragglers, independent draw.
        if plan.disk_degrade_frac > 0.0 {
            let k = ((plan.disk_degrade_frac * slaves.len() as f64).round() as usize)
                .clamp(1, slaves.len());
            let mut pool = slaves.clone();
            rng.shuffle(&mut pool);
            let (lo, hi) = plan.disk_degrade_onset_s;
            for &n in pool.iter().take(k) {
                let at = rng.range(lo, hi.max(lo + 1e-9));
                events.push(FaultEvent {
                    at,
                    node: n,
                    kind: FaultKind::DiskDegrade { factor: plan.disk_degrade_factor },
                });
            }
        }

        // Deterministic order: by time, then node, then kind rank.
        events.sort_by(|a, b| {
            a.at.total_cmp(&b.at).then(a.node.cmp(&b.node)).then(kind_rank(a.kind).cmp(&kind_rank(b.kind)))
        });
        // Never kill the whole slave set: a dead cluster can neither
        // place replicas nor finish a job (the engine would panic or
        // idle forever). Keep the earliest `slaves - 1` crashes, drop
        // the rest — fixed schedules included.
        let crash_cap = slaves.len().saturating_sub(1);
        let mut crashed: Vec<usize> = Vec::new();
        events.retain(|e| {
            if e.kind != FaultKind::Crash {
                return true;
            }
            if crashed.len() < crash_cap && !crashed.contains(&e.node) {
                crashed.push(e.node);
                true
            } else {
                false
            }
        });
        FaultSchedule { events, speculation: plan.speculation }
    }
}

fn kind_rank(k: FaultKind) -> u8 {
    match k {
        FaultKind::Crash => 0,
        FaultKind::RackCrash => 1,
        FaultKind::Straggle { .. } => 2,
        FaultKind::DiskDegrade { .. } => 3,
        FaultKind::RackBrownout { .. } => 4,
    }
}

/// Derive the fault-event RNG stream seed from a scenario's **stable id**
/// (never from insertion order): the same scenario gets the same faults
/// under any `--threads` value and any grid reshape.
pub fn fault_stream_seed(scenario_seed: u64, scenario_id: &str) -> u64 {
    crate::sweep::grid::derive_seed(scenario_seed ^ 0xFA17_FA17_FA17_FA17, scenario_id)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_generates_nothing() {
        let p = InjectionPlan::empty();
        assert!(p.is_empty());
        let s = FaultSchedule::generate(&p, 7, 9);
        assert!(s.events.is_empty());
        assert!(!s.speculation);
    }

    #[test]
    fn fixed_crashes_pass_through() {
        let p = InjectionPlan {
            crashes: vec![CrashSpec { node: 3, at: 12.0 }, CrashSpec { node: 0, at: 1.0 }],
            ..InjectionPlan::empty()
        };
        assert!(!p.is_empty());
        let s = FaultSchedule::generate(&p, 7, 9);
        // The master entry is dropped; the slave crash survives.
        assert_eq!(s.events.len(), 1);
        assert_eq!(s.events[0].node, 3);
        assert_eq!(s.events[0].kind, FaultKind::Crash);
    }

    #[test]
    fn mtbf_sampling_is_deterministic_and_capped() {
        let p = InjectionPlan {
            mtbf_s: Some(100.0),
            max_crashes: 2,
            crash_horizon_s: 1e9,
            ..InjectionPlan::empty()
        };
        let a = FaultSchedule::generate(&p, 42, 9);
        let b = FaultSchedule::generate(&p, 42, 9);
        assert_eq!(a.events, b.events);
        assert!(a.events.len() <= 2);
        assert!(!a.events.is_empty());
        for w in a.events.windows(2) {
            assert!(w[0].at <= w[1].at, "events must be time-sorted");
        }
        // A different stream seed moves the schedule.
        let c = FaultSchedule::generate(&p, 43, 9);
        assert_ne!(a.events, c.events);
    }

    #[test]
    fn stragglers_sampled_from_slaves_only() {
        let p = InjectionPlan { straggler_frac: 0.5, ..InjectionPlan::empty() };
        let s = FaultSchedule::generate(&p, 5, 9);
        assert_eq!(s.events.len(), 4); // round(0.5 * 8)
        for e in &s.events {
            assert!(e.node >= 1 && e.node < 9);
            assert!(matches!(e.kind, FaultKind::Straggle { .. }));
            assert!(e.at >= 5.0 && e.at < 50.0);
        }
        // All distinct nodes.
        let mut nodes: Vec<usize> = s.events.iter().map(|e| e.node).collect();
        nodes.sort_unstable();
        nodes.dedup();
        assert_eq!(nodes.len(), 4);
    }

    #[test]
    fn rack_events_pass_through_sorted() {
        let p = InjectionPlan {
            rack_crashes: vec![RackCrashSpec { rack: 2, at: 30.0 }],
            rack_brownouts: vec![RackBrownoutSpec { rack: 1, at: 5.0, factor: 0.25 }],
            ..InjectionPlan::empty()
        };
        assert!(!p.is_empty() && p.active());
        let s = FaultSchedule::generate(&p, 11, 9);
        assert_eq!(s.events.len(), 2);
        assert_eq!(s.events[0].node, 1);
        assert_eq!(s.events[0].kind, FaultKind::RackBrownout { factor: 0.25 });
        assert_eq!(s.events[1].node, 2);
        assert_eq!(s.events[1].kind, FaultKind::RackCrash);
    }

    #[test]
    fn fault_stream_seed_is_a_pure_function_of_the_id() {
        let a = fault_stream_seed(1, "amdahl-n9-c4-direct-nolzo-search-mtbf600");
        let b = fault_stream_seed(1, "amdahl-n9-c4-direct-nolzo-search-mtbf600");
        let c = fault_stream_seed(1, "amdahl-n9-c2-direct-nolzo-search-mtbf600");
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_ne!(fault_stream_seed(2, "x"), fault_stream_seed(1, "x"));
    }
}
