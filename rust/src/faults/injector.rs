//! Schedules a [`FaultSchedule`]'s events into the engine as timers.
//!
//! Installation is the only entry point the workload drivers call:
//! `install(engine, world, schedule)` arms the world's
//! [`super::FaultState`], starts the background balancer when the
//! schedule carries a [`super::BalancerConfig`], then registers one
//! timer per fault event. An empty schedule with speculation off and no
//! balancer installs **nothing** — no timers, no state transitions —
//! preserving the byte-identity of fault-free runs.

use crate::hdfs::WorldHandle;
use crate::sim::Engine;

use super::plan::{FaultKind, FaultSchedule};
use super::{balancer, recovery};
use crate::cluster::NodeId;

/// Arm fault injection for this run. Call once, after the world is
/// built and before the workload starts (all event times are relative
/// to the current simulated time, normally 0).
pub fn install(engine: &mut Engine, world: &WorldHandle, schedule: &FaultSchedule) {
    if schedule.events.is_empty() && !schedule.speculation && schedule.balancer.is_none() {
        return;
    }
    {
        let mut w = world.borrow_mut();
        let nodes = w.cluster.len();
        w.faults.arm(nodes, schedule.speculation);
    }
    if let Some(cfg) = &schedule.balancer {
        balancer::install(engine, world, cfg.clone());
    }
    for ev in &schedule.events {
        let node = NodeId(ev.node);
        // For the rack-scoped kinds the event's `node` field carries the
        // rack index, not a node id.
        let rack = ev.node;
        let world = world.clone();
        match ev.kind {
            FaultKind::Crash => {
                engine.after(ev.at, move |engine| {
                    recovery::handle_crash(engine, &world, node);
                });
            }
            FaultKind::RackCrash => {
                engine.after(ev.at, move |engine| {
                    recovery::handle_rack_crash(engine, &world, rack);
                });
            }
            FaultKind::Straggle { factor } => {
                engine.after(ev.at, move |engine| {
                    recovery::handle_straggle(engine, &world, node, factor);
                });
            }
            FaultKind::DiskDegrade { factor } => {
                engine.after(ev.at, move |engine| {
                    recovery::handle_disk_degrade(engine, &world, node, factor);
                });
            }
            FaultKind::RackBrownout { factor } => {
                engine.after(ev.at, move |engine| {
                    recovery::handle_rack_brownout(engine, &world, rack, factor);
                });
            }
            FaultKind::Decommission => {
                engine.after(ev.at, move |engine| {
                    recovery::handle_decommission(engine, &world, node);
                });
            }
            FaultKind::Recommission => {
                engine.after(ev.at, move |engine| {
                    recovery::handle_recommission(engine, &world, node);
                });
            }
            FaultKind::RackRecommission => {
                engine.after(ev.at, move |engine| {
                    recovery::handle_rack_recommission(engine, &world, rack);
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::faults::plan::{CrashSpec, FaultSchedule, InjectionPlan, RackCrashSpec};
    use crate::hdfs::World;
    use crate::hw::{amdahl_blade, DiskKind};
    use crate::sim::engine::shared;

    fn world(n: usize, seed: u64) -> (Engine, WorldHandle) {
        let mut e = Engine::new(seed);
        let cluster = Cluster::build(&mut e, &amdahl_blade(DiskKind::Raid0), n);
        let mut w = World::new(cluster);
        w.namenode.set_datanodes((1..n).map(NodeId).collect());
        (e, shared(w))
    }

    #[test]
    fn empty_schedule_installs_nothing() {
        let (mut e, w) = world(4, 1);
        install(&mut e, &w, &FaultSchedule::default());
        assert!(!w.borrow().faults.active);
        e.run();
        assert_eq!(e.events_processed(), 0);
    }

    #[test]
    fn crash_event_marks_node_down_and_blacklists() {
        let (mut e, w) = world(4, 1);
        let plan = InjectionPlan {
            crashes: vec![CrashSpec { node: 2, at: 3.0 }],
            ..InjectionPlan::empty()
        };
        let sched = FaultSchedule::generate(&plan, 9, 4);
        install(&mut e, &w, &sched);
        assert!(w.borrow().faults.active);
        e.run();
        let wb = w.borrow();
        assert!(!wb.faults.is_up(NodeId(2)));
        assert!(wb.namenode.is_dead(NodeId(2)));
        assert!(!wb.namenode.is_live(NodeId(2)));
        assert_eq!(wb.faults.stats.crashes, 1);
        assert!((e.now() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn straggle_event_slows_cpu() {
        let (mut e, w) = world(3, 1);
        let cpu = w.borrow().cluster.node(NodeId(1)).cpu;
        let nominal = e.resource(cpu).capacity;
        let plan = InjectionPlan { straggler_frac: 1.0, ..InjectionPlan::empty() };
        let sched = FaultSchedule::generate(&plan, 5, 3);
        install(&mut e, &w, &sched);
        e.run();
        let slowed = e.resource(cpu).capacity;
        assert!(
            (slowed - nominal * 0.4).abs() < 1e-9,
            "cpu {slowed} should be 0.4 x {nominal}"
        );
        assert_eq!(w.borrow().faults.stats.stragglers, 2);
    }

    #[test]
    fn rack_crash_kills_members_and_uplink_but_spares_other_racks() {
        let mut e = Engine::new(1);
        // 6 nodes, 2 racks: rack 0 = {0,1,2}, rack 1 = {3,4,5}.
        let cluster = Cluster::build_racked(&mut e, &amdahl_blade(DiskKind::Raid0), 6, 2, 2.0);
        let mut w = World::new(cluster);
        w.namenode.set_datanodes((1..6).map(NodeId).collect());
        let w = shared(w);
        let plan = InjectionPlan {
            rack_crashes: vec![RackCrashSpec { rack: 1, at: 2.0 }],
            ..InjectionPlan::empty()
        };
        let sched = FaultSchedule::generate(&plan, 9, 6);
        install(&mut e, &w, &sched);
        e.run();
        let wb = w.borrow();
        for n in [3usize, 4, 5] {
            assert!(!wb.faults.is_up(NodeId(n)), "n{n} should be dead");
            assert!(wb.namenode.is_dead(NodeId(n)));
        }
        assert!(wb.faults.is_up(NodeId(1)) && wb.faults.is_up(NodeId(2)));
        assert_eq!(wb.faults.stats.rack_crashes, 1);
        assert_eq!(wb.faults.stats.crashes, 3);
        let u = wb.cluster.rack_uplink(1).unwrap();
        assert!(
            (e.resource(u.up).capacity - u.capacity_bps * 0.01).abs() < 1e-6,
            "uplink floored after the rack died"
        );
        assert!((e.now() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn crash_then_rejoin_round_trips_the_node() {
        let (mut e, w) = world(4, 1);
        let plan = InjectionPlan {
            crashes: vec![CrashSpec { node: 2, at: 3.0 }],
            rejoin_after_s: Some(7.0),
            ..InjectionPlan::empty()
        };
        let sched = FaultSchedule::generate(&plan, 9, 4);
        assert_eq!(sched.events.len(), 2);
        install(&mut e, &w, &sched);
        e.run();
        let wb = w.borrow();
        assert!(wb.faults.is_up(NodeId(2)), "node must be back up");
        assert!(wb.namenode.is_live(NodeId(2)));
        assert!(wb.namenode.is_placement_target(NodeId(2)));
        assert_eq!(wb.faults.stats.crashes, 1);
        assert_eq!(wb.faults.stats.recommissions, 1);
        let cpu = wb.cluster.node(NodeId(2)).cpu;
        let nominal = wb.cluster.node(NodeId(2)).spec.cpu.capacity;
        assert!((e.resource(cpu).capacity - nominal).abs() < 1e-9);
        assert!((e.now() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn decommission_drains_blocks_then_goes_dead() {
        use crate::faults::plan::DecommissionSpec;
        use crate::hdfs::{BlockMeta, FileMeta};
        let (mut e, w) = world(4, 1);
        {
            let mut wb = w.borrow_mut();
            wb.faults.replication = 2;
            let id = wb.namenode.alloc_block();
            wb.namenode.put_file(
                "f",
                FileMeta {
                    blocks: vec![BlockMeta {
                        id,
                        size: 8.0 * crate::hw::MIB,
                        stored_size: 8.0 * crate::hw::MIB,
                        replicas: vec![NodeId(2), NodeId(3)],
                    }],
                },
            );
        }
        let plan = InjectionPlan {
            decommissions: vec![DecommissionSpec { node: 2, at: 1.0 }],
            ..InjectionPlan::empty()
        };
        let sched = FaultSchedule::generate(&plan, 9, 4);
        install(&mut e, &w, &sched);
        e.run();
        let wb = w.borrow();
        assert_eq!(wb.faults.stats.decommissions, 1);
        assert!(!wb.faults.is_up(NodeId(2)), "drained node ends dead");
        assert!(wb.namenode.is_dead(NodeId(2)));
        assert!(!wb.namenode.is_decommissioning(NodeId(2)));
        // The block kept its factor without ever being lost: the copy
        // moved off the draining node before it left.
        let b = &wb.namenode.get_file("f").unwrap().blocks[0];
        assert_eq!(b.replicas.len(), 2, "{:?}", b.replicas);
        assert!(!b.replicas.contains(&NodeId(2)));
        assert_eq!(wb.faults.stats.rereplications_done, 1);
        assert_eq!(wb.faults.stats.blocks_lost, 0);
    }

    #[test]
    fn disk_degrade_survives_stream_recomputation() {
        let (mut e, w) = world(2, 1);
        let disk = w.borrow().cluster.node(NodeId(1)).disk;
        {
            let mut wb = w.borrow_mut();
            wb.faults.arm(2, false);
            wb.cluster.set_disk_degrade(&mut e, NodeId(1), 0.5);
        }
        assert!((e.resource(disk).capacity - 0.5).abs() < 1e-12);
        {
            let mut wb = w.borrow_mut();
            wb.cluster.disk_stream_start(&mut e, NodeId(1), true);
        }
        // RAID0 single-stream eff is 1.0; the degrade multiplier must
        // persist through the recomputation.
        assert!((e.resource(disk).capacity - 0.5).abs() < 1e-12);
        {
            let mut wb = w.borrow_mut();
            wb.cluster.disk_stream_end(&mut e, NodeId(1), true);
        }
        assert!((e.resource(disk).capacity - 0.5).abs() < 1e-12);
    }
}
