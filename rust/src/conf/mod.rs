//! Hadoop configuration (paper Table 1) plus the application-level knobs
//! the paper's §3.4 experiments toggle, and cluster presets.
//!
//! The key names mirror Hadoop v0.20.2's XML keys so the config prints
//! exactly like the paper's Table 1.

pub mod cli;

use crate::hw::{DiskKind, MIB};

/// Hadoop + experiment configuration.
#[derive(Debug, Clone)]
pub struct HadoopConf {
    /// `dfs.replication` — 1 or 3 in the paper's experiments.
    pub dfs_replication: usize,
    /// `dfs.block.size` in bytes (64 MB).
    pub dfs_block_size: f64,
    /// `mapred.child.java.opts` heap (-Xmx512m).
    pub child_heap_mb: usize,
    /// `mapred.job.reuse.jvm.num.tasks` == -1 (always reuse). When false,
    /// each task pays a JVM start cost (~1.5 s on Atom).
    pub reuse_jvm: bool,
    /// `io.sort.mb` — map-side sort buffer (125 MB; §3.1 sizes it so most
    /// mappers spill exactly once).
    pub io_sort_mb: usize,
    /// `io.sort.record.percent` — fraction of the sort buffer reserved
    /// for per-record metadata (0.2; 16 bytes ≈ 4 ints per record).
    pub io_sort_record_percent: f64,
    /// `io.sort.spill.percent` — buffer fill threshold that triggers a
    /// spill (0.8).
    pub io_sort_spill_percent: f64,
    /// `io.bytes.per.checksum` (512 default, 4096 tuned).
    pub io_bytes_per_checksum: usize,
    /// `mapred.tasktracker.reduce.tasks.maximum` (2 for Neighbor
    /// Searching — the DataNode needs CPU — and 3 for Neighbor Statistics).
    pub reduce_slots: usize,
    /// `mapred.tasktracker.map.tasks.maximum` (3).
    pub map_slots: usize,

    // ---- application-level knobs from §3.4 ----
    /// Reducers wrap their OutputStream in a BufferedOutputStream (§3.4.1
    /// fix). When false, every tiny write crosses JNI for the CRC32.
    pub buffered_output: bool,
    /// Bytes per application-level write when NOT buffered (the paper's
    /// Neighbor Searching reducer wrote 8 bytes at a time).
    pub app_write_bytes: usize,
    /// BufferedOutputStream size when buffered.
    pub output_buffer_bytes: usize,
    /// LZO compression of reducer output (§3.4.2).
    pub lzo_output: bool,
    /// LZO compression ratio (output/input ≈ 0.4: "reduces the output
    /// size from the reducers by 60%").
    pub lzo_ratio: f64,
    /// Direct I/O for HDFS DataNode writes (§3.4.3; reads stay buffered —
    /// §3.3: direct reads lack prefetch and regress badly).
    pub direct_io_write: bool,
    /// HDFS data directory device.
    pub data_disk: DiskKind,
    /// Memory-bus copy-capacity override in bytes/s (None = the node
    /// preset's value). The §4 discussion argues more cores alone may
    /// leave the blade memory-bound — this knob lets the sweep chart
    /// the 2-D core × bus frontier.
    pub membus_copy_bps: Option<f64>,
    /// Rack count the cluster is partitioned into (nodes are assigned in
    /// contiguous chunks; node 0, the master, lives in rack 0). 1 = the
    /// paper's flat single-rack fabric, which is byte-identical to the
    /// pre-rack code path (no ToR uplink resources exist).
    pub racks: usize,
    /// ToR uplink oversubscription ratio: aggregate in-rack NIC bandwidth
    /// divided by the rack's uplink bandwidth. 1.0 = non-blocking fabric.
    /// Only meaningful with `racks > 1`.
    pub rack_oversub: f64,
}

impl Default for HadoopConf {
    /// The paper's tuned Table 1 configuration.
    fn default() -> Self {
        HadoopConf {
            dfs_replication: 3,
            dfs_block_size: 64.0 * MIB,
            child_heap_mb: 512,
            reuse_jvm: true,
            io_sort_mb: 125,
            io_sort_record_percent: 0.2,
            io_sort_spill_percent: 0.8,
            io_bytes_per_checksum: 4096,
            reduce_slots: 2,
            map_slots: 3,
            buffered_output: true,
            app_write_bytes: 8,
            output_buffer_bytes: 64 * 1024,
            lzo_output: false,
            lzo_ratio: 0.4,
            direct_io_write: false,
            data_disk: DiskKind::Raid0,
            membus_copy_bps: None,
            racks: 1,
            rack_oversub: 1.0,
        }
    }
}

impl HadoopConf {
    /// The untuned baseline the paper's Fig 3 "original" bars use:
    /// unbuffered 8-byte writes, 512-byte checksums, no LZO, no direct I/O.
    pub fn fig3_baseline(replication: usize) -> Self {
        HadoopConf {
            dfs_replication: replication,
            io_bytes_per_checksum: 512,
            buffered_output: false,
            lzo_output: false,
            direct_io_write: false,
            ..HadoopConf::default()
        }
    }

    /// Effective bytes moved per JNI checksum crossing on the reducer
    /// output path (§3.4.1): unbuffered, every `app_write_bytes` write
    /// crosses JNI; buffered, one crossing per checksum chunk.
    pub fn jni_call_stride(&self) -> f64 {
        if self.buffered_output {
            self.io_bytes_per_checksum as f64
        } else {
            self.app_write_bytes as f64
        }
    }

    /// Render as the paper's Table 1.
    pub fn render_table1(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!("{:<38} {}\n", "dfs.replication", self.dfs_replication));
        s.push_str(&format!(
            "{:<38} {}MB\n",
            "dfs.block.size",
            (self.dfs_block_size / MIB) as u64
        ));
        s.push_str(&format!(
            "{:<38} -Xmx{}m\n",
            "mapred.child.java.opts", self.child_heap_mb
        ));
        s.push_str(&format!(
            "{:<38} {}\n",
            "mapred.job.reuse.jvm.num.tasks",
            if self.reuse_jvm { "-1" } else { "1" }
        ));
        s.push_str(&format!("{:<38} {}\n", "io.sort.mb", self.io_sort_mb));
        s.push_str(&format!(
            "{:<38} {}\n",
            "io.sort.record.percent", self.io_sort_record_percent
        ));
        s.push_str(&format!(
            "{:<38} {}\n",
            "io.sort.spill.percent", self.io_sort_spill_percent
        ));
        s.push_str(&format!(
            "{:<38} {}\n",
            "io.bytes.per.checksum", self.io_bytes_per_checksum
        ));
        s.push_str(&format!(
            "{:<38} {}\n",
            "mapred.tasktracker.reduce.tasks.maximum", self.reduce_slots
        ));
        s.push_str(&format!(
            "{:<38} {}\n",
            "mapred.tasktracker.map.tasks.maximum", self.map_slots
        ));
        s
    }

    /// Apply a `key=value` override using Hadoop key names (for the CLI).
    pub fn set(&mut self, key: &str, value: &str) -> anyhow::Result<()> {
        match key {
            "dfs.replication" => self.dfs_replication = value.parse()?,
            "dfs.block.size" => self.dfs_block_size = value.parse::<f64>()?,
            "io.sort.mb" => self.io_sort_mb = value.parse()?,
            "io.sort.record.percent" => self.io_sort_record_percent = value.parse()?,
            "io.sort.spill.percent" => self.io_sort_spill_percent = value.parse()?,
            "io.bytes.per.checksum" => self.io_bytes_per_checksum = value.parse()?,
            "mapred.tasktracker.reduce.tasks.maximum" => self.reduce_slots = value.parse()?,
            "mapred.tasktracker.map.tasks.maximum" => self.map_slots = value.parse()?,
            "mapred.job.reuse.jvm.num.tasks" => self.reuse_jvm = value == "-1",
            "app.buffered.output" => self.buffered_output = value.parse()?,
            "app.lzo" => self.lzo_output = value.parse()?,
            "app.direct.io" => self.direct_io_write = value.parse()?,
            "hw.membus.bps" => self.membus_copy_bps = Some(value.parse::<f64>()?),
            "hw.racks" => self.racks = value.parse()?,
            "hw.rack.oversub" => self.rack_oversub = value.parse()?,
            "app.data.disk" => {
                self.data_disk = match value {
                    "hdd" => DiskKind::Hdd,
                    "ssd" => DiskKind::Ssd,
                    "raid0" => DiskKind::Raid0,
                    other => anyhow::bail!("unknown disk kind {other}"),
                }
            }
            other => anyhow::bail!("unknown configuration key {other}"),
        }
        Ok(())
    }
}

/// Which physical cluster a scenario runs on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ClusterPreset {
    /// Nine Amdahl blades: one master + eight slaves (paper §3.1).
    Amdahl,
    /// Four OCC nodes: one master + three data nodes (paper §3.5).
    Occ,
    /// Hypothetical N-core-Atom blades (paper §4 ablation).
    AmdahlNCore(usize),
    /// Fully parameterized Amdahl cluster: total node count (including
    /// the master) and Atom cores per blade — the sweep grid's cluster
    /// axes (§4 generalized across the whole design space).
    AmdahlSized { nodes: usize, cores: usize },
    /// Fully parameterized OCC cluster: total node count (including the
    /// master) and Opteron cores per node, so OCC-family sweeps honor
    /// the node/core axes symmetrically with [`ClusterPreset::AmdahlSized`].
    /// `OccSized { nodes: 4, cores: 2 }` is the paper's §3.5 testbed.
    OccSized { nodes: usize, cores: usize },
}

impl ClusterPreset {
    /// Total node count of this preset, master included.
    pub fn node_count(self) -> usize {
        match self {
            ClusterPreset::Amdahl | ClusterPreset::AmdahlNCore(_) => 9,
            ClusterPreset::Occ => 4,
            ClusterPreset::AmdahlSized { nodes, .. } => nodes,
            ClusterPreset::OccSized { nodes, .. } => nodes,
        }
    }

    /// Worker (slave) node count — node 0 is always the master.
    pub fn slave_count(self) -> usize {
        self.node_count() - 1
    }

    /// CPU cores per node in this preset.
    pub fn core_count(self) -> usize {
        match self {
            ClusterPreset::Amdahl | ClusterPreset::Occ => 2,
            ClusterPreset::AmdahlNCore(cores) => cores,
            ClusterPreset::AmdahlSized { cores, .. } => cores,
            ClusterPreset::OccSized { cores, .. } => cores,
        }
    }

    /// Node spec for this preset with the configuration's hardware
    /// overrides applied (data-disk kind, optional memory-bus capacity).
    pub fn node_spec_for(self, conf: &HadoopConf) -> crate::hw::NodeSpec {
        let mut spec = self.node_spec(conf.data_disk);
        if let Some(b) = conf.membus_copy_bps {
            assert!(b > 0.0, "membus override must be positive");
            spec.net.membus_copy_bps = b;
        }
        spec
    }

    /// The per-node hardware spec of this preset with `disk` as the
    /// data device.
    pub fn node_spec(self, disk: DiskKind) -> crate::hw::NodeSpec {
        match self {
            ClusterPreset::Amdahl => crate::hw::amdahl_blade(disk),
            ClusterPreset::AmdahlNCore(n) => crate::hw::presets::amdahl_blade_ncore(disk, n),
            ClusterPreset::AmdahlSized { cores, .. } => {
                crate::hw::presets::amdahl_blade_ncore(disk, cores)
            }
            ClusterPreset::Occ => crate::hw::occ_node(),
            ClusterPreset::OccSized { cores, .. } => crate::hw::presets::occ_node_ncore(cores),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_paper_table1() {
        let c = HadoopConf::default();
        assert_eq!(c.dfs_replication, 3);
        assert!((c.dfs_block_size / MIB - 64.0).abs() < 1e-9);
        assert_eq!(c.io_sort_mb, 125);
        assert_eq!(c.io_bytes_per_checksum, 4096);
        assert_eq!(c.map_slots, 3);
    }

    #[test]
    fn table1_render_contains_all_keys() {
        let s = HadoopConf::default().render_table1();
        for key in [
            "dfs.replication",
            "dfs.block.size",
            "mapred.child.java.opts",
            "mapred.job.reuse.jvm.num.tasks",
            "io.sort.mb",
            "io.sort.record.percent",
            "io.sort.spill.percent",
            "io.bytes.per.checksum",
            "mapred.tasktracker.reduce.tasks.maximum",
            "mapred.tasktracker.map.tasks.maximum",
        ] {
            assert!(s.contains(key), "missing {key}");
        }
    }

    #[test]
    fn jni_stride_buffered_vs_not() {
        let mut c = HadoopConf::default();
        c.buffered_output = true;
        assert_eq!(c.jni_call_stride(), 4096.0);
        c.buffered_output = false;
        assert_eq!(c.jni_call_stride(), 8.0);
    }

    #[test]
    fn fig3_baseline_is_untuned() {
        let c = HadoopConf::fig3_baseline(1);
        assert_eq!(c.dfs_replication, 1);
        assert_eq!(c.io_bytes_per_checksum, 512);
        assert!(!c.buffered_output && !c.lzo_output && !c.direct_io_write);
    }

    #[test]
    fn set_overrides() {
        let mut c = HadoopConf::default();
        c.set("dfs.replication", "1").unwrap();
        c.set("app.data.disk", "ssd").unwrap();
        assert_eq!(c.dfs_replication, 1);
        assert_eq!(c.data_disk, DiskKind::Ssd);
        assert!(c.set("bogus.key", "1").is_err());
    }

    #[test]
    fn presets_node_counts() {
        assert_eq!(ClusterPreset::Amdahl.node_count(), 9);
        assert_eq!(ClusterPreset::Occ.node_count(), 4);
        assert_eq!(ClusterPreset::Amdahl.slave_count(), 8);
        assert_eq!(ClusterPreset::Occ.slave_count(), 3);
    }

    #[test]
    fn occ_sized_preset_parameterizes_both_axes() {
        let p = ClusterPreset::OccSized { nodes: 6, cores: 4 };
        assert_eq!(p.node_count(), 6);
        assert_eq!(p.slave_count(), 5);
        assert_eq!(p.core_count(), 4);
        assert_eq!(p.node_spec(DiskKind::Raid0).cpu.cores, 4);
        // The 4-node 2-core OccSized is exactly the paper's fixed OCC rig.
        let fixed = ClusterPreset::Occ.node_spec(DiskKind::Raid0);
        let sized = ClusterPreset::OccSized { nodes: 4, cores: 2 }.node_spec(DiskKind::Raid0);
        assert_eq!(sized.cpu.cores, fixed.cpu.cores);
        assert!((sized.cpu.capacity - fixed.cpu.capacity).abs() < 1e-12);
        assert!((sized.power_full_w - fixed.power_full_w).abs() < 1e-9);
        assert!((sized.power_idle_w - fixed.power_idle_w).abs() < 1e-9);
    }

    #[test]
    fn sized_preset_parameterizes_both_axes() {
        let p = ClusterPreset::AmdahlSized { nodes: 5, cores: 4 };
        assert_eq!(p.node_count(), 5);
        assert_eq!(p.slave_count(), 4);
        assert_eq!(p.core_count(), 4);
        assert_eq!(p.node_spec(DiskKind::Raid0).cpu.cores, 4);
        assert_eq!(ClusterPreset::Amdahl.core_count(), 2);
        assert_eq!(ClusterPreset::AmdahlNCore(6).core_count(), 6);
    }
}
