//! Minimal argument parser for the `amdahl-hadoop` binary and the bench
//! harnesses. (clap is unavailable in this offline environment; this
//! supports `--key value`, `--key=value`, `--flag`, and positionals.)

use std::collections::HashMap;

/// Parsed command line: subcommand, flags, options, positionals.
#[derive(Debug, Default)]
pub struct Args {
    /// First non-flag token.
    pub subcommand: Option<String>,
    /// `--key value` / `--key=value` options.
    pub options: HashMap<String, String>,
    /// Bare `--flag` switches.
    pub flags: Vec<String>,
    /// Non-flag tokens after the subcommand.
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    /// Parse an iterator of tokens. The first non-flag token becomes the
    /// subcommand; later non-flag tokens are positionals.
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Args {
        let mut out = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    /// Was the bare switch `--name` passed?
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// The raw value of option `--name`, if present.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// Parse option `--name` as f64, falling back to `default`.
    pub fn get_f64(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(name) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    /// Parse option `--name` as usize, falling back to `default`.
    pub fn get_usize(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(name) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    /// Parse option `--name` as u64, falling back to `default`.
    pub fn get_u64(&self, name: &str, default: u64) -> anyhow::Result<u64> {
        match self.get(name) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|t| t.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("fig3 extra --replication 3 --seed=42 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("fig3"));
        assert_eq!(a.get("replication"), Some("3"));
        assert_eq!(a.get("seed"), Some("42"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["extra".to_string()]);
    }

    #[test]
    fn trailing_flag() {
        let a = parse("run --fast");
        assert!(a.flag("fast"));
        assert!(a.get("fast").is_none());
    }

    #[test]
    fn typed_getters() {
        let a = parse("x --n 5 --scale 0.25");
        assert_eq!(a.get_usize("n", 1).unwrap(), 5);
        assert!((a.get_f64("scale", 1.0).unwrap() - 0.25).abs() < 1e-12);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
        assert!(parse("x --n five").get_usize("n", 1).is_err());
    }
}
