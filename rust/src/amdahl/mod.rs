//! Amdahl-number analysis (paper §4, Table 4).
//!
//! Amdahl's I/O law: a balanced system does one bit of sequential I/O per
//! second per instruction per second. The paper computes, per Hadoop task
//! class:
//!
//! * **Freq** — observed clock / nominal clock (the ondemand governor
//!   drops the clock on I/O-wait-heavy tasks),
//! * **IPC** — instructions per cycle per core,
//! * **InstrRate** — million instructions/s executed across the package
//!   (2 cores × freq × IPC),
//! * **AD** — Amdahl number counting *disk* bits only,
//! * **ADN** — Amdahl number counting disk *and* network I/O.
//!
//! Reverse-engineering Table 4's arithmetic (see DESIGN.md): the displayed
//! `InstrRate × AD` equals the task's disk bit-rate, and `ADN/AD` equals
//! `disk/(disk+net)` byte ratios for every row (1/3 for HDFS r=3 paths,
//! 1/2 for mappers reading via local sockets). We therefore compute
//!
//! ```text
//! AD  = disk_bits_per_sec / instr_per_sec
//! ADN = AD × disk_bytes / (disk_bytes + net_bytes)
//! ```
//!
//! which reproduces the published rows given the paper's own Freq/IPC
//! calibration. The byte tallies come from [`Counters`], fed by every
//! HDFS/MapReduce operation; CPU-seconds come from the engine's per-class
//! usage integrals.

pub mod balance;

use std::collections::BTreeMap;

use crate::cluster::Cluster;
use crate::hw::cpu::{CpuSpec, TaskClass};
use crate::sim::Engine;

/// Byte tallies per task prefix (e.g. `"hdfs-write"`, `"mapper"`).
#[derive(Debug, Default, Clone)]
pub struct IoTally {
    /// Bytes that touched a disk device.
    pub disk_bytes: f64,
    /// Bytes that crossed a socket endpoint.
    pub net_bytes: f64,
}

/// Global I/O accounting, fed by the HDFS and MapReduce layers.
#[derive(Debug, Default)]
pub struct Counters {
    // BTreeMap so `tasks()` iterates in name order — report tables built
    // from this iterator are reproducible without a caller-side sort.
    tallies: BTreeMap<String, IoTally>,
}

impl Counters {
    /// Empty counters.
    pub fn new() -> Self {
        Self::default()
    }

    /// Account `bytes` of disk traffic to `task`.
    pub fn add_disk(&mut self, task: &str, bytes: f64) {
        self.tallies.entry(task.to_string()).or_default().disk_bytes += bytes;
    }

    /// Account `bytes` of socket-endpoint traffic to `task`.
    pub fn add_net(&mut self, task: &str, bytes: f64) {
        self.tallies.entry(task.to_string()).or_default().net_bytes += bytes;
    }

    /// The accumulated tally of `task` (zeros when never seen).
    pub fn tally(&self, task: &str) -> IoTally {
        self.tallies.get(task).cloned().unwrap_or_default()
    }

    /// Iterate the task names that accumulated traffic.
    pub fn tasks(&self) -> impl Iterator<Item = &str> {
        self.tallies.keys().map(|s| s.as_str())
    }
}

/// One row of the paper's Table 4.
#[derive(Debug, Clone)]
pub struct AmdahlRow {
    /// Task-class label (Table 4 row name).
    pub task: String,
    /// Observed / nominal clock.
    pub freq: f64,
    /// Instructions per cycle per core.
    pub ipc: f64,
    /// Million instructions per second, whole package.
    pub instr_rate_mips: f64,
    /// Amdahl number, disk I/O only. None when the class does ~no I/O
    /// (the paper prints "N/A" for the stat reducer).
    pub ad: Option<f64>,
    /// Amdahl number, disk + network I/O.
    pub adn: Option<f64>,
}

/// Sum the CPU core-seconds consumed under a task prefix across all nodes.
///
/// Usage classes follow the `"<task>:<op>"` convention from
/// [`crate::cluster::ops`]; this sums every class whose name starts with
/// `task` + `":"` on every node's CPU resource.
pub fn task_cpu_seconds(engine: &Engine, cluster: &Cluster, task: &str) -> f64 {
    let prefix = format!("{task}:");
    let mut total = 0.0;
    for node in &cluster.nodes {
        let r = engine.resource(node.cpu);
        // `busy_classes` iterates in ascending class-id order, so this
        // float sum is bit-stable run to run (the old HashMap iteration
        // order was not).
        for (class, busy) in r.busy_classes() {
            if engine.class_name(class).starts_with(&prefix) {
                total += busy;
            }
        }
    }
    total
}

/// Compute one Table 4 row from simulated tallies.
///
/// * `wall_seconds` — the duration the task class was active (bytes and
///   instructions are both divided by it, so it cancels inside AD; it
///   only scales the displayed InstrRate).
/// * `cpu_core_seconds` — core-seconds the class consumed (from
///   [`task_cpu_seconds`]).
pub fn amdahl_row(
    cpu: &CpuSpec,
    class: TaskClass,
    tally: &IoTally,
    cpu_core_seconds: f64,
    wall_seconds: f64,
) -> AmdahlRow {
    let freq = cpu.freq_ratio(class);
    let ipc = cpu.ipc(class);
    let instr = cpu.instructions(class, cpu_core_seconds);
    let instr_rate = if wall_seconds > 0.0 { instr / wall_seconds } else { 0.0 };
    let disk_bits_rate = if wall_seconds > 0.0 {
        tally.disk_bytes * 8.0 / wall_seconds
    } else {
        0.0
    };
    let (ad, adn) = if instr_rate > 0.0 && tally.disk_bytes > 0.0 {
        let ad = disk_bits_rate / instr_rate;
        let adn = ad * tally.disk_bytes / (tally.disk_bytes + tally.net_bytes);
        (Some(ad), Some(adn))
    } else {
        (None, None)
    };
    AmdahlRow {
        task: class.name().to_string(),
        freq,
        ipc,
        instr_rate_mips: instr_rate / 1e6,
        ad,
        adn,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::cpu::atom330;

    #[test]
    fn counters_accumulate() {
        let mut c = Counters::new();
        c.add_disk("hdfs-write", 100.0);
        c.add_disk("hdfs-write", 50.0);
        c.add_net("hdfs-write", 300.0);
        let t = c.tally("hdfs-write");
        assert_eq!(t.disk_bytes, 150.0);
        assert_eq!(t.net_bytes, 300.0);
        assert_eq!(c.tally("nope").disk_bytes, 0.0);
    }

    #[test]
    fn table4_hdfs_write_row_shape() {
        // Reconstruct the paper's HDFS-write row: r=3 ⇒ net = 2× disk,
        // both cores busy, AD≈1.3 ⇒ disk rate ≈ InstrRate×1.3 bits/s.
        let cpu = atom330();
        let wall = 10.0;
        let instr_rate = cpu.instructions(TaskClass::HdfsWrite, 2.0 * wall) / wall;
        let disk_bytes = 1.3 * instr_rate / 8.0 * wall;
        let tally = IoTally { disk_bytes, net_bytes: 2.0 * disk_bytes };
        let row = amdahl_row(&cpu, TaskClass::HdfsWrite, &tally, 2.0 * wall, wall);
        assert!((row.freq - 0.79).abs() < 1e-12);
        assert!((row.ipc - 0.22).abs() < 1e-12);
        assert!((row.instr_rate_mips - 548.75).abs() / 548.75 < 0.03);
        assert!((row.ad.unwrap() - 1.3).abs() < 1e-9);
        assert!((row.adn.unwrap() - 1.3 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn no_io_yields_no_amdahl_number() {
        // Paper: "The Amdahl number for the Neighbor Statistics application
        // is irrelevant because reducers output little data" → N/A.
        let cpu = atom330();
        let row = amdahl_row(&cpu, TaskClass::ReducerStat, &IoTally::default(), 10.0, 5.0);
        assert!(row.ad.is_none() && row.adn.is_none());
    }
}
