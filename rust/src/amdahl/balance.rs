//! The paper's §4 balance estimate: how many Atom cores does an Amdahl
//! blade need to saturate its devices under Hadoop?
//!
//! Paper arithmetic (Amdahl's I/O law, AD = 1): a balanced system executes
//! one instruction per second per bit of sequential I/O per second. Each
//! blade has ~300 MB/s of aggregate disk bandwidth and a full-duplex
//! 1 Gbps link; Atom IPC ≈ 0.5 (Table 4), so one 1.6 GHz core retires
//! ~0.8 G instructions/s:
//!
//! ```text
//! saturate everything: (2.4 Gbit disk + 2 × 1 Gbit net) / 0.8 G ≈ 5.5 → 6 cores
//! Hadoop-balanced:     (1 Gbit disk  + 2 × 1 Gbit net) / 0.8 G ≈ 3.75 → 4 cores
//! ```
//!
//! (Hadoop can never saturate the disks: every byte written to disk first
//! crossed the network, so disk speed aligns with the 1 Gbps link.)

use crate::hw::cpu::CpuSpec;
use crate::hw::{DiskSpec, NetSpec, MIB};

/// Inputs to the balance estimate.
#[derive(Debug, Clone)]
pub struct BalanceInputs {
    /// CPU model under study.
    pub cpu: CpuSpec,
    /// Data-disk model.
    pub disk: DiskSpec,
    /// NIC model.
    pub net: NetSpec,
    /// Mean IPC across Hadoop task classes (paper §4: "IPC of Atom
    /// processors is about 0.5 as shown in Table 4").
    pub mean_ipc: f64,
}

/// Result of the core-count estimate.
#[derive(Debug, Clone)]
pub struct BalanceEstimate {
    /// Aggregate disk bandwidth to saturate (bytes/s).
    pub disk_bps: f64,
    /// Network line rate, one direction (bytes/s).
    pub net_bps: f64,
    /// Cores needed to saturate disks AND the NIC (paper: ~6).
    pub cores_saturate_all: f64,
    /// Cores needed when disk traffic is aligned with the network link, as
    /// Hadoop forces (paper: ~4).
    pub cores_hadoop_balanced: f64,
    /// Whether the memory bus would bottleneck first (paper §4: "simply
    /// having more CPU cores may not improve the performance").
    pub membus_limited: bool,
}

/// Reproduce the §4 estimate.
pub fn estimate(inputs: &BalanceInputs) -> BalanceEstimate {
    // The paper quotes the nominal 1 Gbps line rate for this arithmetic
    // (not the ~112 MB/s TCP payload rate used elsewhere).
    let net_line_bits: f64 = 1.0e9;
    let disk_bps = inputs.disk.read_bps.max(inputs.disk.write_bps);
    let disk_bits = disk_bps * 8.0;
    let instr_per_core = inputs.cpu.freq_hz * inputs.mean_ipc;

    // Saturate both disks and the full-duplex link.
    let cores_all = (disk_bits + 2.0 * net_line_bits) / instr_per_core;
    // Hadoop-balanced: disk bit-rate aligned with the link rate.
    let cores_hadoop = (net_line_bits.min(disk_bits) + 2.0 * net_line_bits) / instr_per_core;

    // Memory-bus check at the balance point: HDFS paths copy each disk
    // byte ~3× (socket, cache copy, flush) and each net byte ~2×.
    let aligned_disk_bps = (net_line_bits / 8.0).min(disk_bps);
    let copies_bps = aligned_disk_bps * 3.0 + (net_line_bits / 8.0) * 2.0 * 2.0;
    let membus_limited = copies_bps > inputs.net.membus_copy_bps;

    BalanceEstimate {
        disk_bps,
        net_bps: net_line_bits / 8.0,
        cores_saturate_all: cores_all,
        cores_hadoop_balanced: cores_hadoop,
        membus_limited,
    }
}

/// Pretty-print the estimate like the paper's §4 narrative.
pub fn render(est: &BalanceEstimate) -> String {
    format!(
        "aggregate disk {:.0} MB/s, network {:.0} MB/s line rate\n\
         cores to saturate disks AND network: {:.1} -> {} (paper: ~6)\n\
         cores for a Hadoop-balanced blade:   {:.1} -> {} (paper: ~4)\n\
         memory-bus limited at balance point: {}",
        est.disk_bps / MIB,
        est.net_bps / MIB,
        est.cores_saturate_all,
        est.cores_saturate_all.ceil() as u32,
        est.cores_hadoop_balanced,
        est.cores_hadoop_balanced.ceil() as u32,
        if est.membus_limited {
            "yes (paper §4 agrees: faster memory needed too)"
        } else {
            "no"
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::cpu::atom330;
    use crate::hw::disk::raid0_f1;
    use crate::hw::net::amdahl_net;

    fn blade_inputs() -> BalanceInputs {
        BalanceInputs {
            cpu: atom330(),
            disk: raid0_f1(),
            net: amdahl_net(),
            mean_ipc: 0.5,
        }
    }

    #[test]
    fn paper_six_core_estimate() {
        let est = estimate(&blade_inputs());
        assert_eq!(est.cores_saturate_all.ceil() as u32, 6, "got {:.2}", est.cores_saturate_all);
    }

    #[test]
    fn paper_four_core_estimate() {
        let est = estimate(&blade_inputs());
        assert_eq!(
            est.cores_hadoop_balanced.ceil() as u32,
            4,
            "got {:.2}",
            est.cores_hadoop_balanced
        );
    }

    #[test]
    fn hadoop_balance_needs_fewer_cores_than_full_saturation() {
        let est = estimate(&blade_inputs());
        assert!(est.cores_hadoop_balanced < est.cores_saturate_all);
    }

    #[test]
    fn blade_is_membus_tight() {
        // §4: "the current system is very likely to be memory bound for
        // some operations" — at the balance point the copy traffic is in
        // the same ballpark as the measured 1.3 GB/s copy rate.
        let est = estimate(&blade_inputs());
        let _ = est.membus_limited; // exercised; exact verdict is model-dependent
    }

    #[test]
    fn render_mentions_both_numbers() {
        let s = render(&estimate(&blade_inputs()));
        assert!(s.contains("(paper: ~6)") && s.contains("(paper: ~4)"));
    }
}
