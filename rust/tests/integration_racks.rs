//! Integration tests for the multi-rack topology: single-rack
//! byte-identity, rack-aware placement end to end, whole-rack crashes
//! with cross-fabric re-replication, oversubscription throttling, the
//! rack × oversubscription frontier, and determinism across thread
//! counts and solver modes.

use amdahl_hadoop::cluster::{Cluster, NodeId};
use amdahl_hadoop::conf::{ClusterPreset, HadoopConf};
use amdahl_hadoop::faults::{self, FaultSchedule, InjectionPlan, RackCrashSpec};
use amdahl_hadoop::hdfs::testdfsio::write_test_on;
use amdahl_hadoop::hdfs::{write_file, BlockMeta, FileMeta, World, WorldHandle};
use amdahl_hadoop::hw::{amdahl_blade, DiskKind, MIB};
use amdahl_hadoop::sim::engine::shared;
use amdahl_hadoop::sim::{Engine, SolverMode};
use amdahl_hadoop::sweep::{run_sweep, ClusterFamily, SweepGrid, SweepOptions, Workload, WritePath};
use amdahl_hadoop::zones::{run_app, App, ZonesConfig};

/// A racked 9-node world: racks {0,1,2}/{3,4,5}/{6,7,8}, DataNodes on
/// every node but the master (`World::new` arms the NameNode's rack
/// map from the cluster topology).
fn racked_world(seed: u64, racks: usize, oversub: f64) -> (Engine, WorldHandle) {
    let mut e = Engine::new(seed);
    let cluster =
        Cluster::build_racked(&mut e, &amdahl_blade(DiskKind::Raid0), 9, racks, oversub);
    let mut w = World::new(cluster);
    w.namenode.set_datanodes((1..9).map(NodeId).collect());
    assert!(w.namenode.rack_aware(), "World::new must arm the rack map");
    (e, shared(w))
}

fn tiny_opts(threads: usize, solver: SolverMode) -> SweepOptions {
    SweepOptions {
        threads,
        solver,
        dfsio_bytes_per_worker: 32.0 * MIB,
        dfsio_workers: 2,
        ..SweepOptions::default()
    }
}

/// The tentpole invariant: with `--racks 1` (the default) the sweep is
/// byte-identical no matter what the other rack axes say, and the JSON
/// carries no rack keys at all.
#[test]
fn single_rack_sweep_is_byte_identical_and_rack_free() {
    let base = SweepGrid {
        families: vec![ClusterFamily::Amdahl],
        nodes: vec![5],
        cores: vec![1],
        write_paths: vec![WritePath::DirectIo],
        lzo: vec![false],
        workloads: vec![Workload::DfsioWrite, Workload::DfsioRead],
        ..SweepGrid::paper_default(42, 1, 1)
    };
    let noisy = SweepGrid {
        oversub: vec![4.0, 8.0],
        rack_crash_at: vec![None, Some(10.0)],
        ..base.clone()
    };
    let a = run_sweep(&base, &tiny_opts(2, SolverMode::Incremental)).to_json();
    let b = run_sweep(&noisy, &tiny_opts(2, SolverMode::Incremental)).to_json();
    assert_eq!(a, b, "single-rack output must ignore the rack-only axes");
    for key in ["\"racks\"", "\"oversub\"", "rack_crash", "rack0"] {
        assert!(!a.contains(key), "single-rack JSON leaked {key:?}");
    }
}

/// End-to-end rack-aware placement through the real write path: every
/// block spans exactly two racks (client rack + one remote rack holding
/// replicas 2 and 3).
#[test]
fn racked_writes_span_two_racks() {
    let (mut e, w) = racked_world(7, 3, 4.0);
    let conf = HadoopConf { racks: 3, rack_oversub: 4.0, ..HadoopConf::default() };
    for client in [1usize, 4, 7] {
        write_file(
            &mut e,
            &w,
            NodeId(client),
            format!("f{client}"),
            128.0 * MIB,
            &conf,
            "hdfs-write",
            |_| {},
        );
    }
    e.run();
    let wb = w.borrow();
    for client in [1usize, 4, 7] {
        let f = wb.namenode.get_file(&format!("f{client}")).unwrap();
        assert_eq!(f.blocks.len(), 2);
        for b in &f.blocks {
            assert_eq!(b.replicas.len(), 3);
            assert_eq!(b.replicas[0], NodeId(client), "first replica client-local");
            let r0 = wb.cluster.rack_of(b.replicas[0]);
            let r1 = wb.cluster.rack_of(b.replicas[1]);
            let r2 = wb.cluster.rack_of(b.replicas[2]);
            assert_ne!(r1, r0, "replica 2 must leave the client rack: {:?}", b.replicas);
            assert_eq!(r2, r1, "replica 3 shares replica 2's rack: {:?}", b.replicas);
        }
    }
    // The cross-rack pipeline hop actually traversed the ToR uplinks.
    let up_busy: f64 = (0..3)
        .filter_map(|r| wb.cluster.rack_uplink(r))
        .map(|u| e.busy_total(u.up) + e.busy_total(u.down))
        .sum();
    assert!(up_busy > 0.0, "cross-rack writes never touched the fabric");
}

/// A whole-rack crash: every member dies, the uplink goes dark, and
/// every block the rack held is re-replicated **across the fabric**
/// under `recovery:*` — including blocks whose survivors were all in
/// one rack (the repair target must restore the two-rack spread).
#[test]
fn rack_crash_rereplicates_across_the_fabric() {
    let (mut e, w) = racked_world(13, 3, 4.0);
    // Hand-placed blocks so the failure geometry is exact: both blocks
    // keep a single survivor in rack 1 after rack 2 dies.
    {
        let mut wb = w.borrow_mut();
        let id1 = wb.namenode.alloc_block();
        let id2 = wb.namenode.alloc_block();
        wb.namenode.put_file(
            "a",
            FileMeta {
                blocks: vec![BlockMeta {
                    id: id1,
                    size: 64.0 * MIB,
                    stored_size: 64.0 * MIB,
                    replicas: vec![NodeId(3), NodeId(6), NodeId(7)],
                }],
            },
        );
        wb.namenode.put_file(
            "b",
            FileMeta {
                blocks: vec![BlockMeta {
                    id: id2,
                    size: 64.0 * MIB,
                    stored_size: 64.0 * MIB,
                    replicas: vec![NodeId(4), NodeId(7), NodeId(8)],
                }],
            },
        );
    }
    let plan = InjectionPlan {
        rack_crashes: vec![RackCrashSpec { rack: 2, at: 1.0 }],
        ..InjectionPlan::empty()
    };
    let sched = FaultSchedule::generate(&plan, 21, 9);
    faults::install(&mut e, &w, &sched);
    e.run();
    let wb = w.borrow();
    let stats = &wb.faults.stats;
    assert_eq!(stats.rack_crashes, 1);
    assert_eq!(stats.crashes, 3, "nodes 6, 7, 8 all died");
    assert_eq!(stats.blocks_lost, 0, "rack-aware spread keeps every block recoverable");
    assert!(stats.rereplications_done >= 2, "both blocks must be repaired: {stats:?}");
    assert!(stats.recovery_bytes >= 128.0 * MIB);
    for name in ["a", "b"] {
        let b = &wb.namenode.get_file(name).unwrap().blocks[0];
        for r in &b.replicas {
            assert!(r.0 < 6, "replica still on the dead rack: {:?}", b.replicas);
            assert!(wb.faults.is_up(*r));
        }
        // Both lost copies are restored to *distinct* targets (the
        // same-instant repairs share a planned-target set, so they can
        // never collapse onto one node).
        assert_eq!(b.replicas.len(), 3, "block not restored to r=3: {:?}", b.replicas);
        // The two-rack spread is restored: survivors were rack-1-only,
        // so at least one new copy must be in rack 0.
        let racks: std::collections::HashSet<usize> =
            b.replicas.iter().map(|r| wb.cluster.rack_of(*r)).collect();
        assert!(racks.len() >= 2, "block re-concentrated in one rack: {:?}", b.replicas);
    }
    // The repair traffic crossed the fabric: rack 1 uplink (sources) and
    // rack 0 downlink (targets) both carried bytes.
    let u1 = wb.cluster.rack_uplink(1).unwrap();
    let u0 = wb.cluster.rack_uplink(0).unwrap();
    assert!(e.busy_total(u1.up) > 0.0, "recovery sources never sent across the fabric");
    assert!(e.busy_total(u0.down) > 0.0, "recovery targets never received across the fabric");
    // And the dead rack's uplink is floored.
    let u2 = wb.cluster.rack_uplink(2).unwrap();
    assert!((e.resource(u2.up).capacity - u2.capacity_bps * 0.01).abs() < 1e-6);
}

/// ToR oversubscription throttles the cross-rack replica streams the
/// rack-aware policy mandates: the same write workload is materially
/// slower behind a 64:1 fabric than a non-blocking one.
#[test]
fn oversubscription_throttles_cross_rack_writes() {
    let preset = ClusterPreset::AmdahlSized { nodes: 9, cores: 2 };
    let base = HadoopConf { direct_io_write: true, racks: 3, ..HadoopConf::default() };
    let free = write_test_on(
        preset,
        5u64,
        2,
        32.0 * MIB,
        &HadoopConf { rack_oversub: 1.0, ..base.clone() },
    );
    let choked = write_test_on(
        preset,
        5u64,
        2,
        32.0 * MIB,
        &HadoopConf { rack_oversub: 64.0, ..base },
    );
    assert!(
        choked.result.makespan > free.result.makespan * 1.15,
        "64:1 oversubscription should slow cross-rack writes: {:.1}s vs {:.1}s",
        choked.result.makespan,
        free.result.makespan
    );
}

/// Acceptance pin: a `--racks 3 --oversub 4` sweep with a whole-rack
/// crash completes, attributes recovery work, loses no blocks (the
/// rack-aware spread), and renders the rack × oversubscription
/// frontier.
#[test]
fn rack_sweep_with_rack_crash_end_to_end() {
    let g = SweepGrid {
        families: vec![ClusterFamily::Amdahl],
        nodes: vec![9],
        cores: vec![2],
        racks: vec![1, 3],
        oversub: vec![1.0, 4.0],
        rack_crash_at: vec![None, Some(30.0)],
        write_paths: vec![WritePath::DirectIo],
        lzo: vec![false],
        workloads: vec![Workload::DfsioWrite],
        ..SweepGrid::paper_default(42, 2, 2)
    };
    // racks=1 → 1 scenario; racks=3 → 2 oversubs x 2 crash values.
    assert_eq!(g.len(), 5);
    let r = run_sweep(&g, &tiny_opts(2, SolverMode::Incremental));
    let crashed = r
        .records
        .iter()
        .find(|x| x.id.ends_with("-r3-os4-rackdown30"))
        .expect("rack-crash scenario missing");
    let f = crashed.faults.as_ref().expect("rack-crash record must carry fault stats");
    assert_eq!(f.rack_crashes, 1);
    assert_eq!(f.crashes, 3);
    assert_eq!(f.blocks_lost, 0, "rack-aware placement must keep all blocks recoverable");
    assert!(f.recovery_bytes > 0.0, "no cross-fabric re-replication ran: {f:?}");
    assert!(crashed.recovery_joules > 0.0, "recovery energy not attributed");
    // The degraded table pairs it with its fault-free topology twin.
    let rows = r.degraded_rows();
    let row = rows.iter().find(|x| x.id == crashed.id).unwrap();
    assert_eq!(
        row.baseline_id.as_deref(),
        Some("amdahl-n9-c2-direct-nolzo-dfsio-write-r3-os4")
    );
    // The frontier renders one cell per (racks, oversub) point.
    let cells = r.rack_frontier();
    assert_eq!(cells.len(), 3, "flat + r3/os1 + r3/os4: {cells:?}");
    let rendered = amdahl_hadoop::report::render_rack_frontier(&cells);
    assert!(rendered.contains("rack x oversubscription frontier"), "{rendered}");
    assert!(rendered.contains("4:1"), "{rendered}");
    // JSON carries the rack fields for racked scenarios only.
    let json = r.to_json();
    assert!(json.contains("\"racks\": 3"));
    assert!(json.contains("\"rack_crash_at\": 30.000000"));
    assert!(json.contains("\"rack_crashes\": 1"));
}

/// A rack-crashed MapReduce job (rack-local scheduling tier + TaskTracker
/// blacklisting + cross-fabric re-replication) still completes.
#[test]
fn rack_crashed_search_job_completes() {
    let conf = HadoopConf {
        buffered_output: true,
        direct_io_write: true,
        racks: 3,
        rack_oversub: 4.0,
        ..HadoopConf::default()
    };
    let z = ZonesConfig {
        seed: 17,
        scale: 0.0008,
        faults: InjectionPlan {
            rack_crashes: vec![RackCrashSpec { rack: 2, at: 5.0 }],
            ..InjectionPlan::empty()
        },
        ..Default::default()
    };
    let out = run_app(ClusterPreset::Amdahl, &conf, &z, App::Search);
    assert!(out.total_seconds > 0.0, "job must complete despite losing a rack");
    assert_eq!(out.faults.rack_crashes, 1);
    assert_eq!(out.faults.crashes, 3);
    assert!(out.job.hdfs_output_bytes > 0.0);
    assert!(
        out.faults.rereplications_started > 0
            || out.faults.maps_requeued > 0
            || out.faults.reduces_requeued > 0,
        "losing a rack must force recovery work: {:?}",
        out.faults
    );
}

/// A ToR brownout throttles the fabric without killing anything.
#[test]
fn rack_brownout_degrades_uplink_only() {
    let (mut e, w) = racked_world(31, 3, 1.0);
    let plan = InjectionPlan {
        rack_brownouts: vec![amdahl_hadoop::faults::RackBrownoutSpec {
            rack: 1,
            at: 2.0,
            factor: 0.25,
        }],
        ..InjectionPlan::empty()
    };
    let sched = FaultSchedule::generate(&plan, 3, 9);
    faults::install(&mut e, &w, &sched);
    e.run();
    let wb = w.borrow();
    assert_eq!(wb.faults.stats.rack_brownouts, 1);
    assert_eq!(wb.faults.stats.crashes, 0);
    for n in 1..9 {
        assert!(wb.faults.is_up(NodeId(n)));
    }
    let u = wb.cluster.rack_uplink(1).unwrap();
    assert!((e.resource(u.up).capacity - u.capacity_bps * 0.25).abs() < 1e-6);
    assert!((e.resource(u.down).capacity - u.capacity_bps * 0.25).abs() < 1e-6);
}

fn rack_grid(seed: u64) -> SweepGrid {
    SweepGrid {
        families: vec![ClusterFamily::Amdahl],
        nodes: vec![5],
        cores: vec![1],
        racks: vec![2],
        oversub: vec![1.0, 4.0],
        rack_crash_at: vec![None, Some(10.0)],
        write_paths: vec![WritePath::DirectIo],
        lzo: vec![false],
        workloads: vec![Workload::DfsioWrite],
        ..SweepGrid::paper_default(seed, 1, 1)
    }
}

/// CI mini-sweep pin: a 2-rack × oversub grid (with a whole-rack crash
/// scenario in it) is byte-identical under any thread count.
#[test]
fn rack_sweep_is_thread_count_independent() {
    let g = rack_grid(42);
    let a = run_sweep(&g, &tiny_opts(1, SolverMode::Incremental)).to_json();
    let b = run_sweep(&g, &tiny_opts(4, SolverMode::Incremental)).to_json();
    assert_eq!(a, b, "rack sweep output depends on --threads");
    assert!(a.contains("-r2-"), "rack ids missing from the sweep");
}

/// CI mini-sweep pin: both solver modes produce identical simulation
/// outcomes on the racked, rack-crashed grid.
#[test]
fn rack_sweep_is_solver_mode_identical() {
    let g = rack_grid(42);
    let whole = run_sweep(&g, &tiny_opts(2, SolverMode::WholeSet));
    let inc = run_sweep(&g, &tiny_opts(2, SolverMode::Incremental));
    assert_eq!(
        whole.sim_json(),
        inc.sim_json(),
        "solver modes diverged on the rack topology"
    );
}
