//! Integration tests for the scenario-sweep engine: grid expansion,
//! stable ids, cross-run determinism, the core-count frontier, and the
//! incremental-vs-whole-set solver equivalence (the refactor's
//! byte-identical regression gate).

use amdahl_hadoop::hw::MIB;
use amdahl_hadoop::sim::SolverMode;
use amdahl_hadoop::sweep::{
    run_sweep, ClusterFamily, SweepGrid, SweepOptions, Workload, WritePath,
};

fn small_opts() -> SweepOptions {
    SweepOptions {
        threads: 2,
        scale: 0.0008,
        dfsio_bytes_per_worker: 48.0 * MIB,
        dfsio_workers: 4,
        ..SweepOptions::default()
    }
}

#[test]
fn grid_axis_counts_multiply() {
    let g = SweepGrid {
        families: vec![ClusterFamily::Amdahl],
        nodes: vec![5, 9],
        cores: vec![1, 2, 4],
        write_paths: vec![WritePath::OutputBuffered, WritePath::DirectIo],
        lzo: vec![false, true],
        workloads: vec![Workload::DfsioWrite, Workload::Search],
        ..SweepGrid::paper_default(1, 1, 1)
    };
    assert_eq!(g.len(), 2 * 3 * 2 * 2 * 2);
    let scenarios = g.expand();
    assert_eq!(scenarios.len(), g.len());
    // Every id unique.
    let mut ids: Vec<&str> = scenarios.iter().map(|s| s.id.as_str()).collect();
    ids.sort();
    ids.dedup();
    assert_eq!(ids.len(), scenarios.len());
}

#[test]
fn scenario_ids_and_seeds_are_stable_functions_of_the_axes() {
    let g = SweepGrid::paper_default(42, 1, 8);
    let a = g.expand();
    let b = g.expand();
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.seed, y.seed);
    }
    // The acceptance grid: cores 1..8 expands to ≥ 48 scenarios.
    assert!(a.len() >= 48, "paper_default(1..8) = {} scenarios", a.len());
    // Spot-check the id scheme never drifts silently.
    assert!(a.iter().any(|s| s.id == "amdahl-n9-c1-jni-nolzo-dfsio-write"));
    assert!(a.iter().any(|s| s.id == "amdahl-n9-c8-direct-lzo-stat"));
}

#[test]
fn two_sweeps_same_seed_are_byte_identical() {
    let g = SweepGrid {
        families: vec![ClusterFamily::Amdahl],
        nodes: vec![5],
        cores: vec![1, 4],
        write_paths: vec![WritePath::DirectIo],
        lzo: vec![false],
        workloads: vec![Workload::DfsioWrite, Workload::DfsioRead],
        ..SweepGrid::paper_default(42, 1, 1)
    };
    let a = run_sweep(&g, &small_opts());
    let b = run_sweep(&g, &small_opts());
    assert_eq!(a.to_json(), b.to_json(), "sweep output must be deterministic");
    // And a different seed must actually change the measurements' seeds.
    let g2 = SweepGrid { base_seed: 43, ..g.clone() };
    let c = run_sweep(&g2, &small_opts());
    assert_ne!(a.to_json(), c.to_json());
}

/// The solver-refactor regression gate: the component-partitioned
/// incremental solver must reproduce the whole-set baseline's seed-grid
/// results **byte-identically** (records + frontier; the "perf" section
/// is mode-dependent by design and excluded via `sim_json`).
#[test]
fn incremental_and_whole_set_solvers_are_byte_identical_on_the_seed_grid() {
    let g = SweepGrid {
        families: vec![ClusterFamily::Amdahl, ClusterFamily::Occ],
        nodes: vec![5],
        cores: vec![1, 2],
        write_paths: vec![WritePath::DirectIo],
        lzo: vec![false, true],
        workloads: Workload::ALL.to_vec(),
        ..SweepGrid::paper_default(42, 1, 1)
    };
    let baseline = run_sweep(&g, &SweepOptions { solver: SolverMode::WholeSet, ..small_opts() });
    let incremental =
        run_sweep(&g, &SweepOptions { solver: SolverMode::Incremental, ..small_opts() });
    assert_eq!(
        baseline.sim_json(),
        incremental.sim_json(),
        "incremental solver changed simulation outcomes"
    );
    // The speedup must be visible in the counters: the incremental
    // solver performs strictly fewer flow-rate computations.
    let sum = |r: &amdahl_hadoop::sweep::SweepResults| {
        r.records.iter().map(|x| x.stats.flows_resolved).sum::<u64>()
    };
    assert!(
        sum(&incremental) < sum(&baseline),
        "incremental {} flow-resolves should be below whole-set {}",
        sum(&incremental),
        sum(&baseline)
    );
}

#[test]
fn perf_section_present_and_solver_tagged() {
    let g = SweepGrid {
        families: vec![ClusterFamily::Amdahl],
        nodes: vec![5],
        cores: vec![1],
        write_paths: vec![WritePath::DirectIo],
        lzo: vec![false],
        workloads: vec![Workload::DfsioWrite],
        ..SweepGrid::paper_default(7, 1, 1)
    };
    let r = run_sweep(&g, &small_opts());
    let json = r.to_json();
    assert!(json.contains("\"perf\": {"), "perf section missing");
    assert!(json.contains("\"solver\": \"incremental\""));
    assert!(json.contains("\"flows_resolved\""));
    assert!(r.records[0].stats.solves > 0);
    assert!(r.records[0].stats.peak_live_flows > 0);
    // The projection used by the determinism gate has no perf section
    // and is a prefix-compatible subset of the full document.
    assert!(!r.sim_json().contains("\"perf\""));
}

#[test]
fn occ_family_sweeps_the_node_axis() {
    // Two OCC node counts must produce different absolute work (more
    // slaves move more bytes) — the axis used to be ignored entirely.
    let mk = |nodes: usize| SweepGrid {
        families: vec![ClusterFamily::Occ],
        nodes: vec![nodes],
        cores: vec![2],
        write_paths: vec![WritePath::DirectIo],
        lzo: vec![false],
        workloads: vec![Workload::DfsioWrite],
        ..SweepGrid::paper_default(11, 1, 1)
    };
    let small = run_sweep(&mk(3), &small_opts());
    let large = run_sweep(&mk(7), &small_opts());
    assert_eq!(small.records[0].nodes, 3);
    assert_eq!(large.records[0].nodes, 7);
    assert!(
        large.records[0].bytes_moved > small.records[0].bytes_moved * 2.0,
        "more OCC slaves must move proportionally more bytes"
    );
}

#[test]
fn frontier_reproduces_the_papers_four_core_estimate() {
    // The baseline cut of the §5 analysis: dfsio-write, tuned write path,
    // no LZO, nine blades, cores 1..=6.
    let g = SweepGrid {
        families: vec![ClusterFamily::Amdahl],
        nodes: vec![9],
        cores: (1..=6).collect(),
        write_paths: vec![WritePath::DirectIo],
        lzo: vec![false],
        workloads: vec![Workload::DfsioWrite],
        ..SweepGrid::paper_default(42, 1, 1)
    };
    let opts = SweepOptions {
        threads: 0,
        dfsio_bytes_per_worker: 96.0 * MIB,
        dfsio_workers: 4,
        ..SweepOptions::default()
    };
    let results = run_sweep(&g, &opts);
    let f = results.frontier();
    assert_eq!(f.rows.len(), 6);

    // Throughput must be non-decreasing in cores (more CPU never hurts).
    for w in f.rows.windows(2) {
        assert!(
            w[1].per_node_mbps >= w[0].per_node_mbps * 0.99,
            "throughput regressed {:.1} -> {:.1} MB/s at {} cores",
            w[0].per_node_mbps,
            w[1].per_node_mbps,
            w[1].cores
        );
    }
    // At one core the blade is CPU-bound — the paper's whole premise.
    assert_eq!(f.rows[0].bottleneck, "cpu", "1-core blade must be CPU-bound");

    // The analytic §4 estimate is exactly the paper's four cores.
    assert_eq!(f.analytic_cores, 4);
    // The empirical knee lands in the same neighborhood; the headline
    // estimate (empirical, cross-checked analytic) is four.
    if let Some(e) = f.empirical_cores {
        assert!((3..=5).contains(&e), "empirical balance point {e} implausible");
    }
    assert!(
        (3..=5).contains(&f.balanced_cores()),
        "balanced-core estimate {} should be ~4",
        f.balanced_cores()
    );
}

#[test]
fn lzo_and_write_path_axes_change_outcomes() {
    // Sanity: the grid axes actually steer the simulation — the stock
    // JNI write path must be slower than the tuned direct-I/O path for
    // the write-heavy workload.
    let g = SweepGrid {
        families: vec![ClusterFamily::Amdahl],
        nodes: vec![9],
        cores: vec![2],
        write_paths: vec![WritePath::BufferedJni, WritePath::DirectIo],
        lzo: vec![false],
        workloads: vec![Workload::Search],
        ..SweepGrid::paper_default(42, 1, 1)
    };
    let r = run_sweep(&g, &small_opts());
    assert_eq!(r.records.len(), 2);
    let jni = &r.records[0];
    let direct = &r.records[1];
    assert_eq!(jni.write_path, "jni");
    assert_eq!(direct.write_path, "direct");
    assert!(
        jni.seconds > direct.seconds,
        "stock write path {:.1}s should be slower than tuned {:.1}s",
        jni.seconds,
        direct.seconds
    );
}
