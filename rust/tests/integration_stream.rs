//! End-to-end pins for the multi-tenant workload-stream subsystem:
//! seeded arrivals through admission scheduling through concurrent
//! MapReduce jobs, per-tenant latency percentiles, the fair-share
//! benefit for the light tenant, and the byte-determinism contract
//! across sweep worker threads, solver threads, and solver modes.

use amdahl_hadoop::conf::{ClusterPreset, HadoopConf};
use amdahl_hadoop::obs::LatencySummary;
use amdahl_hadoop::sim::{ObsSpec, SolverMode};
use amdahl_hadoop::stream::{run_stream, ArrivalConfig, SchedPolicy, StreamConfig, StreamOutcome};

fn lat_canon(l: &Option<LatencySummary>) -> String {
    match l {
        None => "-".into(),
        Some(l) => format!(
            "n={} mean={:.6} p50={:.6} p95={:.6} p99={:.6}",
            l.count, l.mean_s, l.p50_s, l.p95_s, l.p99_s
        ),
    }
}

/// Canonical text form of everything observable in a stream outcome.
/// Any nondeterminism across thread counts or solver modes shows up as
/// a byte diff here.
fn canon(out: &StreamOutcome) -> String {
    let mut s = format!(
        "submitted={} completed={} offered={:.6} goodput={:.6} makespan={:.6} joules={:.6}\n\
         all: {}\n",
        out.submitted,
        out.completed,
        out.offered_jobs_per_min,
        out.goodput_jobs_per_min,
        out.makespan_s,
        out.energy.total_joules,
        lat_canon(&out.latency)
    );
    for t in &out.tenants {
        s.push_str(&format!(
            "{}: submitted={} completed={} {}\n",
            t.name, t.submitted, t.completed, lat_canon(&t.latency)
        ));
    }
    s
}

/// A short light stream: enough arrivals to interleave jobs, small
/// enough to run six times in the determinism matrix.
fn light_cfg(sched: SchedPolicy) -> StreamConfig {
    StreamConfig {
        arrival: ArrivalConfig { rate_per_min: 4.0, horizon_s: 120.0, ..Default::default() },
        scale: 0.002,
        sched,
        ..Default::default()
    }
}

/// A saturating stream: heavy-class jobs demand most of the admission
/// pool (13 of 16 slots at the default 0.4% scale), so queues form and
/// the two policies genuinely reorder admissions.
fn saturating_cfg(sched: SchedPolicy) -> StreamConfig {
    StreamConfig {
        arrival: ArrivalConfig { rate_per_min: 10.0, horizon_s: 180.0, ..Default::default() },
        sched,
        ..Default::default()
    }
}

#[test]
fn two_tenant_stream_completes_and_reports_percentiles() {
    let conf = HadoopConf::default();
    let cfg = StreamConfig {
        obs: ObsSpec { metrics: true, ..Default::default() },
        ..light_cfg(SchedPolicy::Fifo)
    };
    let out = run_stream(ClusterPreset::Amdahl, &conf, &cfg);
    assert!(out.submitted > 0, "the horizon must produce arrivals");
    assert_eq!(out.completed, out.submitted, "every submitted job must complete");

    let lat = out.latency.as_ref().expect("aggregate percentiles populated");
    assert_eq!(lat.count as usize, out.completed);
    assert!(lat.p50_s > 0.0);
    assert!(lat.p95_s >= lat.p50_s && lat.p99_s >= lat.p95_s);

    assert_eq!(out.tenants.len(), 2);
    assert_eq!(out.tenants.iter().map(|t| t.submitted).sum::<usize>(), out.submitted);
    for t in &out.tenants {
        assert_eq!(t.completed, t.submitted, "{} must finish its jobs", t.name);
        match &t.latency {
            Some(l) => assert_eq!(l.count as usize, t.completed),
            None => assert_eq!(t.submitted, 0, "{} ran jobs but has no percentiles", t.name),
        }
    }

    // Metrics were armed, so the registry carries the stream families.
    let obs = out.obs.as_ref().expect("obs report present when metrics armed");
    let mj = obs.metrics_json.as_ref().expect("metrics json emitted");
    assert!(mj.contains("stream.job_latency_s"));
    assert!(mj.contains("stream.jobs_submitted"));

    // The human-facing render names every tenant plus the aggregate.
    let txt = amdahl_hadoop::report::render_stream_outcome(&out);
    assert!(txt.contains("multi-tenant stream"));
    assert!(txt.contains("t0") && txt.contains("t1") && txt.contains("all"));
}

#[test]
fn fair_share_beats_fifo_on_light_tenant_p99() {
    let conf = HadoopConf::default();
    let fifo = run_stream(ClusterPreset::Amdahl, &conf, &saturating_cfg(SchedPolicy::Fifo));
    let fair = run_stream(ClusterPreset::Amdahl, &conf, &saturating_cfg(SchedPolicy::Fair));

    // The admission policy must not change the arrival process.
    assert_eq!(fifo.submitted, fair.submitted);
    assert_eq!(
        fifo.tenants.iter().map(|t| t.submitted).collect::<Vec<_>>(),
        fair.tenants.iter().map(|t| t.submitted).collect::<Vec<_>>()
    );

    // Tenant 0 is the light interactive tenant: under FIFO its small
    // jobs queue behind the heavy tenant's full-catalog backlog, under
    // fair-share they are admitted round-robin inside their quota.
    let fifo_light = fifo.tenants[0].latency.as_ref().expect("light tenant ran jobs");
    let fair_light = fair.tenants[0].latency.as_ref().expect("light tenant ran jobs");
    assert!(
        fair_light.p99_s < fifo_light.p99_s,
        "fair-share must shield the light tenant's tail under saturation \
         (fair p99 {:.2}s vs fifo p99 {:.2}s)",
        fair_light.p99_s,
        fifo_light.p99_s
    );
    assert!(
        fair_light.mean_s <= fifo_light.mean_s,
        "fair-share must not worsen the light tenant's mean latency \
         (fair {:.2}s vs fifo {:.2}s)",
        fair_light.mean_s,
        fifo_light.mean_s
    );
}

#[test]
fn stream_bytes_are_invariant_across_solver_threads_and_modes() {
    let conf = HadoopConf::default();
    let cfg = |solver: SolverMode, solver_threads: usize| StreamConfig {
        solver,
        solver_threads,
        ..light_cfg(SchedPolicy::Fair)
    };
    let reference = canon(&run_stream(
        ClusterPreset::Amdahl,
        &conf,
        &cfg(SolverMode::Incremental, 1),
    ));
    assert!(reference.contains("t0:"), "canonical form lists tenants");
    for solver in [SolverMode::Incremental, SolverMode::WholeSet] {
        for solver_threads in [1usize, 2, 4] {
            let got = canon(&run_stream(ClusterPreset::Amdahl, &conf, &cfg(solver, solver_threads)));
            assert_eq!(
                got, reference,
                "stream outcome must be byte-identical for {solver:?} x {solver_threads} \
                 solver threads"
            );
        }
    }
}

#[test]
fn stream_sweep_json_is_invariant_across_worker_threads() {
    use amdahl_hadoop::sweep::{run_sweep, SweepGrid, SweepOptions, Workload, WritePath};
    let mut g = SweepGrid::paper_default(42, 1, 1);
    g.workloads = vec![Workload::Search];
    g.write_paths = vec![WritePath::DirectIo];
    g.lzo = vec![false];
    g.arrival = vec![None, Some(6.0)];
    g.sched = vec![SchedPolicy::Fifo, SchedPolicy::Fair];
    let opts = |threads: usize| SweepOptions {
        threads,
        progress: false,
        stream_arrival: ArrivalConfig { horizon_s: 90.0, ..Default::default() },
        ..Default::default()
    };
    let j1 = run_sweep(&g, &opts(1)).to_json();
    let j2 = run_sweep(&g, &opts(2)).to_json();
    let j4 = run_sweep(&g, &opts(4)).to_json();
    assert_eq!(j1, j2, "sweep bytes must not depend on worker thread count");
    assert_eq!(j1, j4, "sweep bytes must not depend on worker thread count");
    assert!(j1.contains("\"stream\": {"), "stream scenarios carry stream records");
}
