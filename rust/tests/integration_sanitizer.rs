//! Integration tests for the simsan runtime invariant sanitizer.
//!
//! The contract under test: arming the sanitizer (`Count` or `Panic`)
//! on a hostile grid — racked topology, fault injection, lifecycle
//! churn, the background balancer — finds **zero** invariant
//! violations, changes **zero** output bytes, and the `Panic` mode
//! actually fires (with scenario context) when a violation is
//! reported. The engine-level check implementations live next to the
//! engine; these tests exercise the full stack.

use amdahl_hadoop::conf::{ClusterPreset, HadoopConf};
use amdahl_hadoop::hdfs::testdfsio;
use amdahl_hadoop::hw::MIB;
use amdahl_hadoop::sim::{Engine, Sanitize, SimConfig, SolverMode};
use amdahl_hadoop::sweep::{
    run_sweep, ClusterFamily, SweepGrid, SweepOptions, Workload, WritePath,
};
use amdahl_hadoop::zones::{run_app, App, ZonesConfig};

/// The determinism-hostile grid from the parallel-solver tests: 3
/// oversubscribed racks, an MTBF crash axis, a decommission, re-join
/// churn, and the balancer — every subsystem that stresses the
/// settle/commit boundaries the sanitizer checks.
fn churn_grid() -> SweepGrid {
    SweepGrid {
        families: vec![ClusterFamily::Amdahl],
        nodes: vec![6],
        cores: vec![2],
        write_paths: vec![WritePath::DirectIo],
        lzo: vec![false],
        workloads: vec![Workload::DfsioWrite],
        racks: vec![3],
        oversub: vec![4.0],
        mtbf: vec![None, Some(300.0)],
        rejoin: vec![Some(60.0)],
        decommission_at: vec![Some(40.0)],
        balancer: vec![None, Some(0.2)],
        ..SweepGrid::paper_default(42, 1, 1)
    }
}

fn opts(solver: SolverMode, solver_threads: usize, sanitize: Sanitize) -> SweepOptions {
    SweepOptions {
        threads: 2,
        dfsio_bytes_per_worker: 32.0 * MIB,
        dfsio_workers: 2,
        solver,
        solver_threads,
        sanitize,
        ..SweepOptions::default()
    }
}

/// The acceptance bar: the panic-armed sanitizer stays silent across
/// 1 / 2 / 4 solver threads and both solver modes on the churn grid,
/// and the simulation-outcome projection is byte-identical to the
/// unarmed run.
#[test]
fn armed_churn_grid_is_clean_and_byte_identical() {
    let g = churn_grid();
    let off = run_sweep(&g, &opts(SolverMode::Incremental, 1, Sanitize::Off));
    for threads in [1, 2, 4] {
        let armed = run_sweep(&g, &opts(SolverMode::Incremental, threads, Sanitize::Panic));
        assert_eq!(
            off.sim_json(),
            armed.sim_json(),
            "panic-armed sanitizer changed sim bytes at {threads} solver threads"
        );
    }
    let ws = run_sweep(&g, &opts(SolverMode::WholeSet, 4, Sanitize::Panic));
    assert_eq!(off.sim_json(), ws.sim_json(), "whole-set armed run changed sim bytes");
}

/// Count mode on a clean run: zero tallied violations, no
/// `san_violations` key in the perf JSON, and the full `to_json`
/// output (perf section included) keeps the unarmed bytes.
#[test]
fn clean_count_mode_emits_no_counter_and_same_bytes() {
    let g = SweepGrid {
        families: vec![ClusterFamily::Amdahl],
        nodes: vec![5],
        cores: vec![2],
        write_paths: vec![WritePath::DirectIo],
        lzo: vec![false],
        workloads: vec![Workload::DfsioWrite],
        ..SweepGrid::paper_default(7, 1, 1)
    };
    let off = run_sweep(&g, &opts(SolverMode::Incremental, 1, Sanitize::Off));
    let counted = run_sweep(&g, &opts(SolverMode::Incremental, 1, Sanitize::Count));
    for r in &counted.records {
        assert_eq!(r.stats.san_violations, 0, "{}: sanitizer tallied a violation", r.id);
    }
    assert!(
        !counted.to_json().contains("san_violations"),
        "clean run leaked the san_violations key"
    );
    assert_eq!(off.to_json(), counted.to_json(), "count mode changed output bytes");
}

/// Single-run TestDFSIO path: armed vs unarmed runs land on identical
/// outcomes (the energy-conservation check runs at finish either way).
#[test]
fn dfsio_clean_under_panic_sanitizer() {
    let conf = HadoopConf::default();
    let run = |san: Sanitize| {
        let sim = SimConfig::new(42).with_sanitize(san);
        testdfsio::write_test_on(ClusterPreset::Amdahl, sim, 2, 16.0 * MIB, &conf)
    };
    let off = run(Sanitize::Off);
    let armed = run(Sanitize::Panic);
    assert_eq!(off.result.makespan.to_bits(), armed.result.makespan.to_bits());
    assert_eq!(off.result.per_node_mbps.to_bits(), armed.result.per_node_mbps.to_bits());
    assert_eq!(armed.stats.san_violations, 0);
}

/// Both Zones applications (the two-step Stat pipeline included) run
/// clean under the panic-armed sanitizer.
#[test]
fn apps_clean_under_panic_sanitizer() {
    let conf = HadoopConf { reduce_slots: 3, ..Default::default() };
    for app in [App::Search, App::Stat] {
        let z = ZonesConfig {
            seed: 17,
            scale: 0.0008,
            kernel_every: usize::MAX,
            sanitize: Sanitize::Panic,
            ..Default::default()
        };
        let out = run_app(ClusterPreset::Amdahl, &conf, &z, app);
        assert!(out.total_seconds > 0.0);
        assert_eq!(out.stats.san_violations, 0);
    }
}

/// Count mode tallies reported violations into `EngineStats`.
#[test]
fn count_mode_tallies_violations() {
    let e = Engine::from_config(SimConfig::new(1).with_sanitize(Sanitize::Count));
    e.san_violation("test-check", "first".to_string());
    e.san_violation("test-check", "second".to_string());
    assert_eq!(e.stats().san_violations, 2);
}

/// Off mode is inert even when a violation is reported.
#[test]
fn off_mode_ignores_reports() {
    let e = Engine::from_config(SimConfig::new(1).with_sanitize(Sanitize::Off));
    e.san_violation("test-check", "ignored".to_string());
    assert_eq!(e.stats().san_violations, 0);
}

/// Panic mode aborts with the check name and scenario label.
#[test]
#[should_panic(expected = "simsan[test-check]")]
fn panic_mode_panics_with_context() {
    let mut e = Engine::from_config(SimConfig::new(1).with_sanitize(Sanitize::Panic));
    e.set_sanitize_label("sanity-fixture");
    e.san_violation("test-check", "deliberate".to_string());
}

/// The `simsan` cargo feature flips the default from `Off` to `Count`.
#[test]
fn sanitize_default_follows_feature() {
    if cfg!(feature = "simsan") {
        assert_eq!(Sanitize::default(), Sanitize::Count);
    } else {
        assert_eq!(Sanitize::default(), Sanitize::Off);
    }
}
