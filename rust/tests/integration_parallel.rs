//! Integration tests for the intra-engine parallel solver: every output
//! — sweep records, trace / metrics exports, single-run outcomes — is
//! byte-identical across `--solver-threads` values and both solver
//! modes, and the thread-dependent perf counters never leak into the
//! simulation-outcome projection.
//!
//! The engine-level guarantees (the pool actually dispatches, partition
//! order, serial fallback below the dispatch floor) live in the
//! `sim::engine` unit tests; these tests exercise the full stack —
//! racked topologies, fault injection, lifecycle churn, the balancer,
//! HDFS pipelines, MapReduce — on top of them.

use amdahl_hadoop::conf::{ClusterPreset, HadoopConf};
use amdahl_hadoop::hdfs::testdfsio;
use amdahl_hadoop::hw::MIB;
use amdahl_hadoop::sim::{ObsSpec, SimConfig, SolverMode};
use amdahl_hadoop::sweep::{
    run_sweep, ClusterFamily, SweepGrid, SweepOptions, Workload, WritePath,
};
use amdahl_hadoop::zones::{run_app, App, ZonesConfig};

/// A deliberately hostile grid for determinism: 3 racks with an
/// oversubscribed fabric, an MTBF crash axis, a graceful decommission,
/// crash → re-join churn, and the background balancer — every subsystem
/// that re-pushes events through the settle barrier.
fn churn_grid() -> SweepGrid {
    SweepGrid {
        families: vec![ClusterFamily::Amdahl],
        nodes: vec![6],
        cores: vec![2],
        write_paths: vec![WritePath::DirectIo],
        lzo: vec![false],
        workloads: vec![Workload::DfsioWrite],
        racks: vec![3],
        oversub: vec![4.0],
        mtbf: vec![None, Some(300.0)],
        rejoin: vec![Some(60.0)],
        decommission_at: vec![Some(40.0)],
        balancer: vec![None, Some(0.2)],
        ..SweepGrid::paper_default(42, 1, 1)
    }
}

fn churn_opts(solver: SolverMode, solver_threads: usize, trace_dir: Option<String>) -> SweepOptions {
    SweepOptions {
        threads: 2,
        dfsio_bytes_per_worker: 32.0 * MIB,
        dfsio_workers: 2,
        solver,
        solver_threads,
        obs: ObsSpec::full(10.0),
        trace_dir,
        ..SweepOptions::default()
    }
}

/// The tentpole bar, end to end: the simulation-outcome projection of a
/// racked, faulted, lifecycle-churning sweep is byte-identical across
/// 1 / 2 / 4 solver threads in both solver modes — and the per-scenario
/// trace / metrics exports are byte-identical files.
#[test]
fn sweep_outputs_byte_identical_across_solver_threads_and_modes() {
    let g = churn_grid();
    let dir = |tag: &str| {
        std::env::temp_dir().join(format!("amdahl-par-int-{}-{tag}", std::process::id()))
    };
    let tagged = |tag: &str| Some(dir(tag).to_string_lossy().into_owned());

    let r1 = run_sweep(&g, &churn_opts(SolverMode::Incremental, 1, tagged("t1")));
    let r2 = run_sweep(&g, &churn_opts(SolverMode::Incremental, 2, None));
    let r4 = run_sweep(&g, &churn_opts(SolverMode::Incremental, 4, tagged("t4")));
    assert_eq!(r1.sim_json(), r2.sim_json(), "sim_json diverged at 2 solver threads");
    assert_eq!(r1.sim_json(), r4.sim_json(), "sim_json diverged at 4 solver threads");

    let w1 = run_sweep(&g, &churn_opts(SolverMode::WholeSet, 1, None));
    let w4 = run_sweep(&g, &churn_opts(SolverMode::WholeSet, 4, None));
    assert_eq!(w1.sim_json(), w4.sim_json(), "whole-set sim_json diverged at 4 threads");
    assert_eq!(
        r1.sim_json(),
        w4.sim_json(),
        "solver modes diverged under the parallel engine"
    );

    for sc in g.expand() {
        for kind in ["trace", "metrics"] {
            let name = format!("{}.{kind}.json", sc.id);
            let a = std::fs::read(dir("t1").join(&name)).expect("threads=1 export missing");
            let b = std::fs::read(dir("t4").join(&name)).expect("threads=4 export missing");
            assert_eq!(a, b, "{name} diverged across solver-thread counts");
        }
    }
    let _ = std::fs::remove_dir_all(dir("t1"));
    let _ = std::fs::remove_dir_all(dir("t4"));
}

/// The perf-section contract: `solver_threads` / `parallel_solves`
/// appear in `to_json` only when the sweep ran multi-threaded, and never
/// in `sim_json` — the default output keeps its exact historical bytes.
#[test]
fn parallel_counters_gate_on_thread_count() {
    let g = SweepGrid {
        families: vec![ClusterFamily::Amdahl],
        nodes: vec![5],
        cores: vec![2],
        write_paths: vec![WritePath::DirectIo],
        lzo: vec![false],
        workloads: vec![Workload::DfsioWrite],
        ..SweepGrid::paper_default(7, 1, 1)
    };
    let opts = |solver_threads: usize| SweepOptions {
        threads: 1,
        dfsio_bytes_per_worker: 32.0 * MIB,
        dfsio_workers: 2,
        solver_threads,
        ..SweepOptions::default()
    };
    let r1 = run_sweep(&g, &opts(1));
    let j1 = r1.to_json();
    assert!(!j1.contains("solver_threads"), "single-threaded perf JSON grew a new key");
    assert!(!j1.contains("parallel_solves"), "single-threaded perf JSON grew a new key");

    let r4 = run_sweep(&g, &opts(4));
    let j4 = r4.to_json();
    assert!(j4.contains("\"solver_threads\": 4"), "multi-threaded perf JSON lost the echo");
    assert!(j4.contains("\"parallel_solves\": "), "multi-threaded perf JSON lost the counter");
    assert!(!r4.sim_json().contains("solver_threads"), "perf counter leaked into sim_json");
    assert_eq!(r1.sim_json(), r4.sim_json(), "thread count changed a simulation outcome");
}

/// Single-scenario dfsio path (`dfsio --solver-threads N`): replication 1
/// across 8 workers keeps the write pipelines component-disjoint, so the
/// batch unions span many components; results and obs exports must still
/// be bit-identical at every thread count.
#[test]
fn dfsio_identical_across_solver_threads() {
    fn run(threads: usize) -> (u64, u64, String, String) {
        let conf = HadoopConf { dfs_replication: 1, ..Default::default() };
        let sim = SimConfig::new(42)
            .with_solver_threads(threads)
            .with_obs(ObsSpec::full(5.0));
        let run = testdfsio::write_test_on(ClusterPreset::Amdahl, sim, 8, 16.0 * MIB, &conf);
        let obs = run.obs.expect("obs was armed");
        (
            run.result.makespan.to_bits(),
            run.result.per_node_mbps.to_bits(),
            obs.trace_json.expect("trace armed"),
            obs.metrics_json.expect("metrics armed"),
        )
    }
    let base = run(1);
    for threads in [2, 4] {
        let r = run(threads);
        assert_eq!(base.0, r.0, "dfsio makespan diverged at {threads} solver threads");
        assert_eq!(base.1, r.1, "dfsio throughput diverged at {threads} solver threads");
        assert_eq!(base.2, r.2, "dfsio trace diverged at {threads} solver threads");
        assert_eq!(base.3, r.3, "dfsio metrics diverged at {threads} solver threads");
    }
}

/// Single-scenario application path (`search --solver-threads N`): the
/// full MapReduce pipeline — ingest, map, shuffle, reduce, HDFS output —
/// lands on identical outcomes and identical energy at every thread
/// count.
#[test]
fn search_app_identical_across_solver_threads() {
    fn run(threads: usize) -> (u64, u64, u64) {
        let conf = HadoopConf {
            buffered_output: true,
            direct_io_write: true,
            ..Default::default()
        };
        let z = ZonesConfig {
            seed: 17,
            scale: 0.0008,
            kernel_every: usize::MAX,
            kernels: None,
            solver_threads: threads,
            ..Default::default()
        };
        let out = run_app(ClusterPreset::Amdahl, &conf, &z, App::Search);
        (
            out.total_seconds.to_bits(),
            out.energy.total_joules.to_bits(),
            out.job.map_locality.to_bits(),
        )
    }
    let base = run(1);
    for threads in [2, 4] {
        let r = run(threads);
        assert_eq!(base.0, r.0, "search makespan diverged at {threads} solver threads");
        assert_eq!(base.1, r.1, "search energy diverged at {threads} solver threads");
        assert_eq!(base.2, r.2, "search locality diverged at {threads} solver threads");
    }
}
