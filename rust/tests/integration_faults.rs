//! Integration tests for the fault-injection & recovery subsystem:
//! the empty-plan identity invariant, crash → re-replication → job
//! completion end to end, mid-block pipeline/read failover, speculative
//! execution, and determinism across thread counts and solver modes.

use amdahl_hadoop::cluster::{Cluster, NodeId};
use amdahl_hadoop::conf::{ClusterPreset, HadoopConf};
use amdahl_hadoop::faults::{self, CrashSpec, FaultSchedule, InjectionPlan};
use amdahl_hadoop::hdfs::{read_file, write_file, BlockMeta, FileMeta, ReadOpts, World, WorldHandle};
use amdahl_hadoop::hw::{amdahl_blade, DiskKind, MIB};
use amdahl_hadoop::sim::engine::shared;
use amdahl_hadoop::sim::{Engine, SolverMode};
use amdahl_hadoop::sweep::{run_sweep, ClusterFamily, SweepGrid, SweepOptions, Workload, WritePath};
use amdahl_hadoop::zones::{run_app, App, ZonesConfig};

fn world(n: usize, seed: u64) -> (Engine, WorldHandle) {
    let mut e = Engine::new(seed);
    let cluster = Cluster::build(&mut e, &amdahl_blade(DiskKind::Raid0), n);
    let mut w = World::new(cluster);
    w.namenode.set_datanodes((1..n).map(NodeId).collect());
    (e, shared(w))
}

/// The tentpole invariant: a sweep with all fault/bus axes at their
/// defaults emits records with the historical ids and no fault keys —
/// the serialized bytes carry nothing from this subsystem.
#[test]
fn fault_free_sweep_json_carries_no_fault_fields() {
    let g = SweepGrid {
        families: vec![ClusterFamily::Amdahl],
        nodes: vec![5],
        cores: vec![1],
        write_paths: vec![WritePath::DirectIo],
        lzo: vec![false],
        workloads: vec![Workload::DfsioWrite],
        ..SweepGrid::paper_default(42, 1, 1)
    };
    let opts = SweepOptions {
        threads: 2,
        dfsio_bytes_per_worker: 32.0 * MIB,
        dfsio_workers: 2,
        ..SweepOptions::default()
    };
    let json = run_sweep(&g, &opts).to_json();
    for key in ["mtbf", "straggler", "speculation", "recovery", "membus_bps", "crashes"] {
        assert!(!json.contains(key), "fault-free JSON leaked key {key:?}");
    }
    assert!(json.contains("\"id\": \"amdahl-n5-c1-direct-nolzo-dfsio-write\""));
}

/// Crash a replica holder after a file is written: the NameNode must
/// purge it and re-replication must restore every block to the full
/// replication factor on the survivors.
#[test]
fn crash_rereplicates_blocks_back_to_full_factor() {
    let (mut e, w) = world(9, 33);
    let conf = HadoopConf::default();
    write_file(&mut e, &w, NodeId(1), "f", 192.0 * MIB, &conf, "hdfs-write", |_| {});
    e.run();
    let victim = {
        let wb = w.borrow();
        wb.namenode.get_file("f").unwrap().blocks[0].replicas[1]
    };
    let plan = InjectionPlan {
        crashes: vec![CrashSpec { node: victim.0, at: 1.0 }],
        ..InjectionPlan::empty()
    };
    let sched = FaultSchedule::generate(&plan, 5, 9);
    faults::install(&mut e, &w, &sched);
    e.run();
    let wb = w.borrow();
    let stats = &wb.faults.stats;
    assert_eq!(stats.crashes, 1);
    assert!(stats.rereplications_done >= 1, "no re-replication ran");
    assert!(stats.recovery_bytes >= 64.0 * MIB, "recovery bytes {:.0}", stats.recovery_bytes);
    assert_eq!(stats.blocks_lost, 0);
    for b in &wb.namenode.get_file("f").unwrap().blocks {
        assert!(!b.replicas.contains(&victim), "dead replica still listed");
        assert_eq!(b.replicas.len(), 3, "block {} not restored to r=3", b.id);
        for r in &b.replicas {
            assert!(wb.faults.is_up(*r), "replica on dead node");
        }
    }
}

/// Kill a DataNode in the middle of a block write: the pipeline must
/// fail over to the survivors mid-block, commit, and top the block back
/// up to the replication factor.
#[test]
fn write_pipeline_fails_over_mid_block() {
    // Pass 1 (fault-free, same seed): discover the pipeline layout and
    // the block-write duration. Determinism makes pass 2 identical up
    // to the crash instant.
    fn run(crash: Option<(usize, f64)>) -> (Engine, WorldHandle, bool) {
        let (mut e, w) = world(9, 44);
        if let Some((node, at)) = crash {
            let plan = InjectionPlan {
                crashes: vec![CrashSpec { node, at }],
                ..InjectionPlan::empty()
            };
            let sched = FaultSchedule::generate(&plan, 7, 9);
            faults::install(&mut e, &w, &sched);
        }
        let conf = HadoopConf::default();
        let done = shared(false);
        let d = done.clone();
        write_file(&mut e, &w, NodeId(1), "f", 64.0 * MIB, &conf, "hdfs-write", move |_| {
            *d.borrow_mut() = true;
        });
        e.run();
        let ok = *done.borrow();
        (e, w, ok)
    }
    let (e0, w0, ok0) = run(None);
    assert!(ok0);
    let duration = e0.now();
    let victim = {
        let wb = w0.borrow();
        // A non-client member of the pipeline.
        wb.namenode.get_file("f").unwrap().blocks[0].replicas[1]
    };
    let (_e1, w1, ok1) = run(Some((victim.0, duration * 0.4)));
    assert!(ok1, "write did not complete after mid-block failover");
    let wb = w1.borrow();
    let stats = &wb.faults.stats;
    assert_eq!(stats.pipeline_failovers, 1, "expected exactly one pipeline failover");
    assert_eq!(stats.writes_aborted, 0);
    let b = &wb.namenode.get_file("f").unwrap().blocks[0];
    assert!(!b.replicas.contains(&victim));
    assert_eq!(b.replicas.len(), 3, "commit + top-up must restore r=3");
    for r in &b.replicas {
        assert!(wb.faults.is_up(*r));
    }
}

/// Kill the serving replica in the middle of a remote block read: the
/// client must re-stream the remaining bytes from a surviving replica.
#[test]
fn read_fails_over_to_surviving_replica() {
    fn run(crash: Option<(usize, f64)>) -> (Engine, WorldHandle, bool) {
        let (mut e, w) = world(9, 55);
        {
            let mut wb = w.borrow_mut();
            let id = wb.namenode.alloc_block();
            wb.namenode.put_file(
                "r/f",
                FileMeta {
                    blocks: vec![BlockMeta {
                        id,
                        size: 64.0 * MIB,
                        stored_size: 64.0 * MIB,
                        replicas: vec![NodeId(2), NodeId(3)],
                    }],
                },
            );
        }
        if let Some((node, at)) = crash {
            let plan = InjectionPlan {
                crashes: vec![CrashSpec { node, at }],
                ..InjectionPlan::empty()
            };
            let sched = FaultSchedule::generate(&plan, 9, 9);
            faults::install(&mut e, &w, &sched);
        }
        let conf = HadoopConf::default();
        let done = shared(false);
        let d = done.clone();
        read_file(&mut e, &w, NodeId(5), "r/f", &conf, ReadOpts::default(), "hdfs-read", move |_| {
            *d.borrow_mut() = true;
        });
        e.run();
        let ok = *done.borrow();
        (e, w, ok)
    }
    // Pass 1: discover which replica served the read (its disk is busy).
    let (e0, w0, ok0) = run(None);
    assert!(ok0);
    let duration = e0.now();
    let src = {
        let wb = w0.borrow();
        let d2 = e0.busy_total(wb.cluster.node(NodeId(2)).disk);
        let d3 = e0.busy_total(wb.cluster.node(NodeId(3)).disk);
        assert!(d2 > 0.0 || d3 > 0.0, "no disk served the read");
        if d2 > d3 {
            2
        } else {
            3
        }
    };
    // Pass 2: kill the server mid-read.
    let (_e1, w1, ok1) = run(Some((src, duration * 0.5)));
    assert!(ok1, "read did not complete after source death");
    let wb = w1.borrow();
    assert_eq!(wb.faults.stats.read_failovers, 1);
    assert_eq!(wb.faults.stats.blocks_lost, 0);
}

/// Acceptance pin, end to end: a seeded TaskTracker/DataNode crash in
/// the middle of a MapReduce job → blacklisting, lost-output
/// re-execution, block re-replication — and the job still completes.
#[test]
fn crashed_node_job_completes_end_to_end() {
    let conf = HadoopConf {
        buffered_output: true,
        direct_io_write: true,
        ..Default::default()
    };
    let faulted = ZonesConfig {
        seed: 17,
        scale: 0.0008,
        faults: InjectionPlan {
            crashes: vec![CrashSpec { node: 3, at: 5.0 }],
            ..InjectionPlan::empty()
        },
        ..Default::default()
    };
    let out = run_app(ClusterPreset::Amdahl, &conf, &faulted, App::Search);
    assert!(out.total_seconds > 0.0, "job must complete despite the crash");
    assert_eq!(out.faults.crashes, 1);
    assert!(out.job.hdfs_output_bytes > 0.0);
    // Every block in the namespace must live on survivors only.
    // (Checked through the recovery counters: something was repaired.)
    assert!(
        out.faults.rereplications_done > 0 || out.faults.maps_requeued > 0,
        "the crash must have forced recovery work: {:?}",
        out.faults
    );
    // The same job fault-free is never slower.
    let clean = ZonesConfig { seed: 17, scale: 0.0008, ..Default::default() };
    let base = run_app(ClusterPreset::Amdahl, &conf, &clean, App::Search);
    assert!(base.faults.crashes == 0 && base.faults.rereplications_done == 0);
    assert!(
        out.total_seconds >= base.total_seconds,
        "faulted {:.1}s vs clean {:.1}s",
        out.total_seconds,
        base.total_seconds
    );
    assert!(out.energy.recovery_joules >= 0.0);
}

/// Stragglers plus 0.20-style speculation: duplicates launch, the map
/// phase recovers most of the straggler damage.
#[test]
fn speculation_hedges_stragglers() {
    let conf = HadoopConf {
        buffered_output: true,
        direct_io_write: true,
        ..Default::default()
    };
    let plan = |spec: bool| InjectionPlan {
        straggler_frac: 0.5,
        straggler_slowdown: 0.15,
        straggler_onset_s: (1.0, 2.0),
        speculation: spec,
        ..InjectionPlan::empty()
    };
    // Scale chosen so the catalog spans several blocks → several maps
    // (speculation needs completed-map statistics to find stragglers).
    let z = |spec: bool| ZonesConfig {
        seed: 23,
        scale: 0.02,
        faults: plan(spec),
        ..Default::default()
    };
    let without = run_app(ClusterPreset::Amdahl, &conf, &z(false), App::Search);
    let with = run_app(ClusterPreset::Amdahl, &conf, &z(true), App::Search);
    assert!(without.faults.stragglers > 0);
    assert_eq!(without.faults.spec_launched, 0);
    assert!(
        with.faults.spec_launched > 0,
        "no speculative attempts launched: {:?}",
        with.faults
    );
    assert!(
        with.job.map_phase < without.job.map_phase,
        "speculation should shorten the straggled map phase: {:.1}s vs {:.1}s",
        with.job.map_phase,
        without.job.map_phase
    );
    assert!(
        with.total_seconds <= without.total_seconds * 1.05,
        "speculation made the job slower: {:.1}s vs {:.1}s",
        with.total_seconds,
        without.total_seconds
    );
}

fn faulted_grid(seed: u64) -> SweepGrid {
    SweepGrid {
        families: vec![ClusterFamily::Amdahl],
        nodes: vec![5],
        cores: vec![2],
        write_paths: vec![WritePath::DirectIo],
        lzo: vec![false],
        workloads: vec![Workload::DfsioWrite, Workload::DfsioRead],
        mtbf: vec![Some(60.0)],
        stragglers: vec![0.25],
        speculation: vec![false],
        ..SweepGrid::paper_default(seed, 1, 1)
    }
}

fn faulted_opts(threads: usize, solver: SolverMode) -> SweepOptions {
    SweepOptions {
        threads,
        solver,
        dfsio_bytes_per_worker: 32.0 * MIB,
        dfsio_workers: 2,
        ..SweepOptions::default()
    }
}

/// Satellite regression: fault RNG streams derive from the scenario's
/// stable id, so a faulted sweep is byte-identical under any thread
/// count.
#[test]
fn faulted_sweep_is_thread_count_independent() {
    let g = faulted_grid(42);
    let a = run_sweep(&g, &faulted_opts(1, SolverMode::Incremental)).to_json();
    let b = run_sweep(&g, &faulted_opts(4, SolverMode::Incremental)).to_json();
    assert_eq!(a, b, "faulted sweep output depends on --threads");
    assert!(a.contains("\"mtbf\""), "faulted records must carry fault fields");
}

/// A seeded crash schedule produces byte-identical simulation outcomes
/// under both solver modes (the incremental engine's equivalence
/// extends to degraded-mode runs).
#[test]
fn faulted_sweep_is_solver_mode_identical() {
    let g = faulted_grid(42);
    let whole = run_sweep(&g, &faulted_opts(2, SolverMode::WholeSet));
    let inc = run_sweep(&g, &faulted_opts(2, SolverMode::Incremental));
    assert_eq!(
        whole.sim_json(),
        inc.sim_json(),
        "solver modes diverged under fault injection"
    );
}

/// The degraded-mode table pairs each faulted scenario with its
/// fault-free twin and reports overheads.
#[test]
fn degraded_rows_pair_with_fault_free_twins() {
    let g = SweepGrid {
        families: vec![ClusterFamily::Amdahl],
        nodes: vec![5],
        cores: vec![2],
        write_paths: vec![WritePath::DirectIo],
        lzo: vec![false],
        workloads: vec![Workload::DfsioWrite],
        mtbf: vec![None, Some(30.0)],
        ..SweepGrid::paper_default(9, 1, 1)
    };
    let r = run_sweep(&g, &faulted_opts(2, SolverMode::Incremental));
    assert_eq!(r.records.len(), 2);
    let rows = r.degraded_rows();
    assert_eq!(rows.len(), 1);
    let row = &rows[0];
    assert!(row.id.ends_with("-mtbf30"), "id {}", row.id);
    assert_eq!(
        row.baseline_id.as_deref(),
        Some("amdahl-n5-c2-direct-nolzo-dfsio-write")
    );
    assert!(row.baseline_seconds > 0.0);
    // (No sign assertion on the slowdown: losing a node can shrink a
    // dfsio makespan — the dead node's writers simply vanish.)
    let report = amdahl_hadoop::report::render_degraded(&rows);
    assert!(report.contains("degraded-mode table"));
    assert!(report.contains(&row.id));
}

/// Satellite: the membus axis changes outcomes when the bus binds, and
/// the 2-D frontier renders one row per bus tier.
#[test]
fn membus_axis_sweeps_and_renders() {
    let g = SweepGrid {
        families: vec![ClusterFamily::Amdahl],
        nodes: vec![5],
        cores: vec![2, 4],
        write_paths: vec![WritePath::DirectIo],
        lzo: vec![false],
        workloads: vec![Workload::DfsioWrite],
        membus: vec![None, Some(50.0 * MIB)],
        ..SweepGrid::paper_default(4, 1, 1)
    };
    let r = run_sweep(&g, &faulted_opts(2, SolverMode::Incremental));
    assert_eq!(r.records.len(), 4);
    let stock2 = r.records.iter().find(|x| x.cores == 2 && x.membus_bps.is_none()).unwrap();
    let slow2 = r.records.iter().find(|x| x.cores == 2 && x.membus_bps.is_some()).unwrap();
    assert!(slow2.id.ends_with("-bus50"), "id {}", slow2.id);
    assert!(
        slow2.per_node_mbps < stock2.per_node_mbps,
        "a 50 MiB/s bus must throttle the write path: {:.1} vs {:.1} MB/s",
        slow2.per_node_mbps,
        stock2.per_node_mbps
    );
    let cells = r.bus_frontier();
    assert_eq!(cells.len(), 4);
    // Bus-major order: the two preset cells first.
    assert!(cells[0].membus_bps.is_none() && cells[1].membus_bps.is_none());
    assert_eq!((cells[0].cores, cells[1].cores), (2, 4));
    let rendered = amdahl_hadoop::report::render_bus_frontier(&cells);
    assert!(rendered.contains("preset"), "{rendered}");
    assert!(rendered.contains("50 MiB/s"), "{rendered}");
    // The faulted sweep JSON carries the bus override.
    assert!(r.to_json().contains("\"membus_bps\""));
}
