//! Integration: HDFS behaviour across the paper's Fig 1 / Fig 2 axes.

use amdahl_hadoop::conf::HadoopConf;
use amdahl_hadoop::hdfs::testdfsio;
use amdahl_hadoop::hw::{DiskKind, MIB};
use amdahl_hadoop::report;

const SZ: f64 = 256.0 * MIB;

#[test]
fn fig1_direct_io_write_wins_most_on_raid0() {
    let rows = report::fig1(42);
    let get = |disk, write, direct| {
        rows.iter()
            .find(|r| r.disk == disk && r.write == write && r.direct == direct)
            .unwrap()
            .mbps
    };
    // Fig 1(c): direct write >> buffered write on RAID0.
    let raid_gain = get(DiskKind::Raid0, true, true) / get(DiskKind::Raid0, true, false);
    let hdd_gain = get(DiskKind::Hdd, true, true) / get(DiskKind::Hdd, true, false);
    assert!(raid_gain > 1.4, "raid0 direct write gain {raid_gain:.2}");
    assert!(raid_gain > hdd_gain, "direct helps RAID0 the most");
    // Fig 1(a): reads unchanged.
    let read_gain = get(DiskKind::Raid0, false, true) / get(DiskKind::Raid0, false, false);
    assert!((read_gain - 1.0).abs() < 0.02, "direct read gain {read_gain:.2}");
}

#[test]
fn fig1_direct_io_kills_flush_cpu() {
    let rows = report::fig1(42);
    for r in &rows {
        if r.write && r.direct {
            assert_eq!(r.cpu_flush_pct, 0.0, "{:?}: flush must be 0% under direct I/O", r.disk);
        }
        if r.write && !r.direct {
            assert!(r.cpu_flush_pct > 50.0, "{:?}: buffered flush is CPU-heavy", r.disk);
        }
    }
}

#[test]
fn table2_matches_paper_numbers() {
    let rows = report::table2(42);
    let local = &rows[0];
    let remote = &rows[1];
    assert!((local.mbps - 343.0).abs() < 10.0, "local {:.0} MB/s", local.mbps);
    assert!((remote.mbps - 112.0).abs() < 3.0, "remote {:.0} MB/s", remote.mbps);
    assert!((remote.cpu_send_pct - 36.76).abs() < 2.0);
    assert!((remote.cpu_recv_pct - 88.1).abs() < 3.0);
    assert!(local.cpu_send_pct > 95.0 && local.cpu_recv_pct > 95.0);
}

#[test]
fn fig2a_shapes() {
    // Direct beats buffered; hardware barely matters; writers 1→2 help.
    let conf = HadoopConf::default();
    let b = testdfsio::write_test(7, 2, SZ, &conf);
    let d = testdfsio::write_test(7, 2, SZ, &HadoopConf { direct_io_write: true, ..conf });
    assert!(d.per_node_mbps > b.per_node_mbps * 1.1, "direct {:.1} vs buffered {:.1}", d.per_node_mbps, b.per_node_mbps);

    let base = HadoopConf { direct_io_write: true, ..Default::default() };
    let raid = testdfsio::write_test(7, 2, SZ, &base);
    let hdd = testdfsio::write_test(7, 2, SZ, &HadoopConf { data_disk: DiskKind::Hdd, ..base.clone() });
    assert!(raid.per_node_mbps / hdd.per_node_mbps < 1.3, "hardware indifference (CPU-bound)");

    let w1 = testdfsio::write_test(7, 1, SZ, &base);
    assert!(raid.per_node_mbps > w1.per_node_mbps, "2 writers beat 1");
}

#[test]
fn fig2b_shapes() {
    let conf = HadoopConf::default();
    // Local >> remote.
    let local = testdfsio::read_test(7, 2, SZ, &conf, false);
    let remote = testdfsio::read_test(7, 2, SZ, &conf, true);
    assert!(local.per_node_mbps > remote.per_node_mbps * 1.2);
    // Single HDD clearly worst at 3 readers, and declining.
    let hdd_conf = HadoopConf { data_disk: DiskKind::Hdd, ..conf.clone() };
    let hdd3 = testdfsio::read_test(7, 3, SZ, &hdd_conf, false);
    let raid3 = testdfsio::read_test(7, 3, SZ, &conf, false);
    assert!(hdd3.per_node_mbps < raid3.per_node_mbps * 0.85, "hdd {:.1} vs raid {:.1}", hdd3.per_node_mbps, raid3.per_node_mbps);
}

#[test]
fn replication_conservation() {
    // Every committed block has exactly r distinct replicas on datanodes.
    use amdahl_hadoop::cluster::{Cluster, NodeId};
    use amdahl_hadoop::hdfs::{write_file, World};
    use amdahl_hadoop::hw::amdahl_blade;
    use amdahl_hadoop::sim::engine::shared;
    use amdahl_hadoop::sim::Engine;

    let mut e = Engine::new(11);
    let cluster = Cluster::build(&mut e, &amdahl_blade(DiskKind::Raid0), 9);
    let mut world = World::new(cluster);
    world.namenode.set_datanodes((1..9).map(NodeId).collect());
    let world = shared(world);
    let conf = HadoopConf::default();
    for i in 0..4 {
        write_file(&mut e, &world, NodeId(1 + i), format!("f{i}"), 200.0 * MIB, &conf, "hdfs-write", |_| {});
    }
    e.run();
    let w = world.borrow();
    for i in 0..4 {
        let f = w.namenode.get_file(&format!("f{i}")).unwrap();
        assert_eq!(f.blocks.len(), 4); // 200 MB / 64 MB
        for b in &f.blocks {
            assert_eq!(b.replicas.len(), 3);
            let mut sorted = b.replicas.clone();
            sorted.sort();
            sorted.dedup();
            assert_eq!(sorted.len(), 3, "replicas distinct");
            assert!(sorted.iter().all(|n| n.0 >= 1 && n.0 <= 8), "replicas on datanodes");
        }
    }
}
