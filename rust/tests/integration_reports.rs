//! Integration: every paper exhibit regenerates with the right shape.

use amdahl_hadoop::report;

#[test]
fn fig3_improvement_shapes() {
    let rows = report::fig3(42, 0.02);
    let get = |label: &str, r: usize| {
        rows.iter().find(|x| x.label == label && x.replication == r).unwrap().seconds
    };
    // §3.4.1: buffering ≈ 2× at r=1, ~47% at r=3.
    let buf1 = get("original (8B writes)", 1) / get("buffer", 1);
    let buf3 = get("original (8B writes)", 3) / get("buffer", 3);
    assert!(buf1 > 1.5 && buf1 < 2.6, "r=1 buffer gain {buf1:.2} (paper ~2.0)");
    assert!(buf3 > 1.25 && buf3 < 1.8, "r=3 buffer gain {buf3:.2} (paper ~1.47)");
    // §3.4.2/3: LZO and direct I/O help at r=3...
    let lzo3 = get("buffer", 3) / get("buffer+lzo", 3);
    let dio3 = get("buffer", 3) / get("buffer+direct", 3);
    assert!(lzo3 > 1.15, "r=3 LZO gain {lzo3:.2} (paper 1.61)");
    assert!(dio3 > 1.05, "r=3 direct gain {dio3:.2} (paper 1.37)");
    // ...and much less at r=1 (paper: ~nothing).
    let lzo1 = get("buffer", 1) / get("buffer+lzo", 1);
    let dio1 = get("buffer", 1) / get("buffer+direct", 1);
    assert!(lzo1 < lzo3, "LZO r=1 {lzo1:.2} must trail r=3 {lzo3:.2}");
    assert!(dio1 < dio3, "direct r=1 {dio1:.2} must trail r=3 {dio3:.2}");
}

#[test]
fn table3_and_energy_shapes() {
    let t3 = report::table3(42, 0.03, None);
    // Runtime orderings.
    assert!(t3.amdahl[0] > t3.amdahl[1] && t3.amdahl[1] > t3.amdahl[2], "θ ordering");
    assert!(t3.occ[0] > t3.amdahl[1], "OCC slower at θ=30 (paper 3901 vs 1628)");
    assert!(t3.occ[1] > t3.amdahl[2], "OCC slower at θ=15 (paper 1760 vs 1069)");
    // Energy ratios in the paper's neighborhood.
    let e = report::energy(&t3);
    assert!(
        e.search_ratio > 4.0 && e.search_ratio < 16.0,
        "search energy ratio {:.1} (paper 7.7)",
        e.search_ratio
    );
    assert!(
        e.stat_ratio > 1.5 && e.stat_ratio < 10.0,
        "stat energy ratio {:.1} (paper 3.4)",
        e.stat_ratio
    );
    assert!(e.search_ratio > e.stat_ratio, "data-intensive advantage is larger");
}

#[test]
fn table4_shapes() {
    let rows = report::table4(42, 0.03);
    let get = |task: &str| rows.iter().find(|r| r.task == task).unwrap();
    let hr = get("HDFS read");
    let hw = get("HDFS write");
    // Paper: HDFS rows have AD ≈ 1 and ADN ≈ AD/3.
    assert!((hr.ad.unwrap() - 1.15).abs() < 0.4, "HDFS read AD {:?}", hr.ad);
    let ratio = hr.adn.unwrap() / hr.ad.unwrap();
    assert!((ratio - 1.0 / 3.0).abs() < 0.08, "ADN/AD {ratio:.2} (paper 0.33)");
    assert!(hw.ad.unwrap() > 0.4 && hw.ad.unwrap() < 2.0);
    // InstrRate ballparks (Minstr/s, paper column 2-cores basis).
    let m = get("Mapper");
    assert!(m.instr_rate_mips > 800.0 && m.instr_rate_mips < 3200.0, "mapper {:.0}", m.instr_rate_mips);
    let rs = get("Reducer (search)");
    assert!(rs.instr_rate_mips > 700.0 && rs.instr_rate_mips < 3000.0, "search {:.0}", rs.instr_rate_mips);
}

#[test]
fn table1_echo() {
    let s = report::table1();
    assert!(s.contains("io.sort.mb") && s.contains("125"));
    assert!(s.contains("dfs.block.size") && s.contains("64MB"));
}

#[test]
fn balance_renders_paper_numbers() {
    let s = report::balance();
    assert!(s.contains("-> 6 (paper: ~6)"), "{s}");
    assert!(s.contains("-> 4 (paper: ~4)"), "{s}");
}
