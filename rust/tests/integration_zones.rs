//! Integration: the Zones applications end to end, kernels included.

use std::rc::Rc;

use amdahl_hadoop::conf::{ClusterPreset, HadoopConf};
use amdahl_hadoop::runtime::PairKernels;
use amdahl_hadoop::zones::{run_app, App, ZonesConfig};

fn zcfg(scale: f64, theta: f64, kernels: Option<Rc<PairKernels>>) -> ZonesConfig {
    ZonesConfig {
        scale,
        theta_arcsec: theta,
        kernel_every: 4,
        kernels,
        ..Default::default()
    }
}

fn search_conf() -> HadoopConf {
    HadoopConf {
        buffered_output: true,
        direct_io_write: true,
        reduce_slots: 2,
        ..Default::default()
    }
}

#[test]
fn theta_scaling_matches_paper_ordering() {
    // Table 3: runtime grows with θ (more output + more pairs).
    let t: Vec<f64> = [15.0, 30.0, 60.0]
        .iter()
        .map(|&th| {
            run_app(ClusterPreset::Amdahl, &search_conf(), &zcfg(0.01, th, None), App::Search)
                .total_seconds
        })
        .collect();
    assert!(t[0] < t[1] && t[1] < t[2], "θ=15/30/60 → {t:?}");
    // Paper 60″/30″ ratio is 2.4; accept a broad band around it.
    let ratio = t[2] / t[1];
    assert!(ratio > 1.5 && ratio < 6.0, "60/30 ratio {ratio:.2} (paper 2.42)");
}

#[test]
fn amdahl_beats_occ_on_data_intensive() {
    let a = run_app(ClusterPreset::Amdahl, &search_conf(), &zcfg(0.01, 30.0, None), App::Search);
    let o = run_app(ClusterPreset::Occ, &search_conf(), &zcfg(0.01, 30.0, None), App::Search);
    let ratio = o.total_seconds / a.total_seconds;
    assert!(ratio > 1.5, "OCC/Amdahl {ratio:.2} (paper 2.4)");
}

#[test]
fn stat_is_closer_race() {
    // §3.5: "The Amdahl cluster has slightly better performance in the
    // compute-intensive application" — the gap must be much smaller than
    // the data-intensive one.
    let conf = HadoopConf { reduce_slots: 3, ..search_conf() };
    let a = run_app(ClusterPreset::Amdahl, &conf, &zcfg(0.01, 60.0, None), App::Stat);
    let o = run_app(ClusterPreset::Occ, &conf, &zcfg(0.01, 60.0, None), App::Stat);
    let stat_ratio = o.total_seconds / a.total_seconds;
    let a2 = run_app(ClusterPreset::Amdahl, &search_conf(), &zcfg(0.01, 30.0, None), App::Search);
    let o2 = run_app(ClusterPreset::Occ, &search_conf(), &zcfg(0.01, 30.0, None), App::Search);
    let search_ratio = o2.total_seconds / a2.total_seconds;
    assert!(stat_ratio > 0.8, "Amdahl should not lose badly: {stat_ratio:.2}");
    assert!(
        stat_ratio < search_ratio,
        "compute-intensive gap {stat_ratio:.2} must be smaller than data-intensive {search_ratio:.2}"
    );
}

#[test]
fn kernel_pairs_match_between_presets() {
    // The science output is a function of the catalog, not the cluster.
    let Some(k) = PairKernels::load_default().ok().map(Rc::new) else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let mut z = zcfg(0.0008, 60.0, Some(k.clone()));
    z.kernel_every = 1; // every block computed → totals independent of partitioning
    let a = run_app(ClusterPreset::Amdahl, &search_conf(), &z, App::Search);
    let o = run_app(ClusterPreset::Occ, &search_conf(), &z, App::Search);
    assert!(a.pairs_found > 0);
    assert_eq!(a.pairs_found, o.pairs_found, "identical catalog → identical pairs");
}

#[test]
fn quad_core_ablation_helps() {
    // §4: a 4-core Atom blade should clearly beat the 2-core one on the
    // CPU-bound search, with diminishing returns after. Slots scale with
    // cores (a real deployment would raise the Table 1 maxima).
    let z = zcfg(0.01, 60.0, None);
    let run_cores = |cores: usize| {
        let conf = HadoopConf {
            map_slots: 3 * cores / 2,
            reduce_slots: cores,
            ..search_conf()
        };
        let preset = if cores == 2 {
            ClusterPreset::Amdahl
        } else {
            ClusterPreset::AmdahlNCore(cores)
        };
        run_app(preset, &conf, &z, App::Search).total_seconds
    };
    let t2 = run_cores(2);
    let t4 = run_cores(4);
    let t8 = run_cores(8);
    assert!(t4 < t2 * 0.8, "4-core {t4:.0}s vs 2-core {t2:.0}s");
    let gain_24 = t2 / t4;
    let gain_48 = t4 / t8;
    assert!(gain_48 < gain_24, "diminishing returns: {gain_24:.2} then {gain_48:.2}");
}
