//! Integration tests for the node lifecycle (decommission → drain →
//! dead → recommission → re-join) and the background rack-aware
//! balancer: the mid-job re-join acceptance pin, block-report
//! resurrection, drain safety, churn determinism across thread counts
//! and solver modes, and the zero-churn byte-identity invariant.

use amdahl_hadoop::cluster::{Cluster, NodeId};
use amdahl_hadoop::conf::{ClusterPreset, HadoopConf};
use amdahl_hadoop::faults::{
    self, BalancerConfig, CrashSpec, FaultSchedule, InjectionPlan,
};
use amdahl_hadoop::hdfs::{BlockMeta, FileMeta, World, WorldHandle};
use amdahl_hadoop::hw::{amdahl_blade, DiskKind, MIB};
use amdahl_hadoop::sim::engine::shared;
use amdahl_hadoop::sim::{Engine, SolverMode};
use amdahl_hadoop::sweep::{run_sweep, ClusterFamily, SweepGrid, SweepOptions, Workload, WritePath};
use amdahl_hadoop::zones::{run_app, App, ZonesConfig};

fn world(n: usize, seed: u64) -> (Engine, WorldHandle) {
    let mut e = Engine::new(seed);
    let cluster = Cluster::build(&mut e, &amdahl_blade(DiskKind::Raid0), n);
    let mut w = World::new(cluster);
    w.namenode.set_datanodes((1..n).map(NodeId).collect());
    (e, shared(w))
}

/// Acceptance pin, end to end: a node crashes in the middle of a
/// MapReduce job, recommissions while the job is still running,
/// re-registers its TaskTracker, and the balancer refills it — the job
/// completes and the rebalance traffic shows up as `balance_joules`.
#[test]
fn crashed_node_recommissions_mid_job_and_balancer_refills_it() {
    let conf = HadoopConf {
        buffered_output: true,
        direct_io_write: true,
        ..Default::default()
    };
    let z = ZonesConfig {
        seed: 29,
        scale: 0.01,
        faults: InjectionPlan {
            crashes: vec![CrashSpec { node: 3, at: 4.0 }],
            rejoin_after_s: Some(8.0),
            balancer: Some(BalancerConfig {
                threshold: 0.1,
                bandwidth_bps: 16.0 * MIB,
                ..BalancerConfig::default()
            }),
            ..InjectionPlan::empty()
        },
        ..Default::default()
    };
    let out = run_app(ClusterPreset::Amdahl, &conf, &z, App::Search);
    assert!(out.total_seconds > 0.0, "job must complete despite the churn");
    assert!(out.job.hdfs_output_bytes > 0.0);
    let f = &out.faults;
    assert_eq!(f.crashes, 1, "{f:?}");
    assert_eq!(f.recommissions, 1, "the crashed node must re-join: {f:?}");
    assert!(
        f.trackers_rejoined >= 1,
        "the TaskTracker must re-register with the live job: {f:?}"
    );
    assert!(
        f.balancer_moves_done >= 1,
        "the re-joined (near-empty) node must receive balancer traffic: {f:?}"
    );
    assert!(f.balance_bytes > 0.0);
    assert!(
        out.energy.balance_joules > 0.0,
        "rebalance traffic must be attributed as balance_joules"
    );
    assert!(
        out.energy.recovery_joules > 0.0,
        "crash repair must still be attributed separately"
    );
    // The same job with no churn: nothing lifecycle-related happens.
    let clean = ZonesConfig { seed: 29, scale: 0.01, ..Default::default() };
    let base = run_app(ClusterPreset::Amdahl, &conf, &clean, App::Search);
    assert_eq!(base.faults.recommissions, 0);
    assert_eq!(base.faults.balancer_moves_done, 0);
    assert_eq!(base.energy.balance_joules, 0.0);
}

/// A re-joining node's block report resurrects data the cluster had no
/// other way to recover: blocks that went under-replicated (no spare
/// target) or fully lost re-register instantly from the intact disk,
/// and the under-replication scan then repairs the rest.
#[test]
fn block_report_resurrects_lost_and_under_replicated_blocks() {
    let (mut e, w) = world(3, 7);
    {
        let mut wb = w.borrow_mut();
        wb.faults.replication = 2;
        let id_a = wb.namenode.alloc_block();
        let id_b = wb.namenode.alloc_block();
        wb.namenode.put_file(
            "a",
            FileMeta {
                blocks: vec![BlockMeta {
                    id: id_a,
                    size: 8.0 * MIB,
                    stored_size: 8.0 * MIB,
                    replicas: vec![NodeId(1), NodeId(2)],
                }],
            },
        );
        wb.namenode.put_file(
            "b",
            FileMeta {
                blocks: vec![BlockMeta {
                    id: id_b,
                    size: 8.0 * MIB,
                    stored_size: 8.0 * MIB,
                    replicas: vec![NodeId(2)],
                }],
            },
        );
    }
    let plan = InjectionPlan {
        crashes: vec![CrashSpec { node: 2, at: 1.0 }],
        rejoin_after_s: Some(4.0),
        ..InjectionPlan::empty()
    };
    let sched = FaultSchedule::generate(&plan, 11, 3);
    faults::install(&mut e, &w, &sched);
    e.run();
    let wb = w.borrow();
    let stats = &wb.faults.stats;
    assert_eq!(stats.crashes, 1);
    assert_eq!(stats.recommissions, 1);
    // Both of node 2's copies came back with it ("a" had dropped to one
    // copy with no spare target; "b" was outright lost).
    assert_eq!(stats.blocks_restored_on_rejoin, 2, "{stats:?}");
    let a = &wb.namenode.get_file("a").unwrap().blocks[0];
    assert_eq!(a.replicas.len(), 2, "{:?}", a.replicas);
    let b = &wb.namenode.get_file("b").unwrap().blocks[0];
    assert!(b.replicas.contains(&NodeId(2)), "lost block must be back: {:?}", b.replicas);
    // The post-rejoin scan topped "b" back up to the factor.
    assert_eq!(b.replicas.len(), 2, "{:?}", b.replicas);
    assert!(wb.faults.is_up(NodeId(2)));
    assert!(wb.namenode.is_live(NodeId(2)));
}

/// Graceful decommission under load: the draining node's in-flight
/// writes finish (nothing is cancelled), every block it held keeps its
/// replication factor, and nothing is ever lost.
#[test]
fn decommission_mid_write_loses_nothing() {
    use amdahl_hadoop::faults::DecommissionSpec;
    let (mut e, w) = world(9, 13);
    let conf = HadoopConf::default();
    // Seed the namespace, then decommission a replica holder. The
    // pre-file is large (several blocks to drain) and the in-flight
    // write small, so the write commits while the drain is still
    // copying — exercising the drain's re-scan.
    amdahl_hadoop::hdfs::write_file(
        &mut e, &w, NodeId(1), "pre", 256.0 * MIB, &conf, "hdfs-write", |_| {},
    );
    e.run();
    let victim = {
        let wb = w.borrow();
        wb.namenode.get_file("pre").unwrap().blocks[0].replicas[1]
    };
    let plan = InjectionPlan {
        decommissions: vec![DecommissionSpec { node: victim.0, at: 0.5 }],
        ..InjectionPlan::empty()
    };
    let sched = FaultSchedule::generate(&plan, 17, 9);
    faults::install(&mut e, &w, &sched);
    // A write already in flight when the drain starts.
    let done = shared(false);
    let d = done.clone();
    amdahl_hadoop::hdfs::write_file(
        &mut e, &w, NodeId(1), "during", 8.0 * MIB, &conf, "hdfs-write", move |_| {
            *d.borrow_mut() = true;
        },
    );
    e.run();
    assert!(*done.borrow(), "the in-flight write must complete");
    let wb = w.borrow();
    let stats = &wb.faults.stats;
    assert_eq!(stats.decommissions, 1);
    assert_eq!(stats.blocks_lost, 0);
    assert_eq!(stats.writes_aborted, 0);
    assert!(!wb.faults.is_up(victim), "drained node ends administratively dead");
    for (name, meta) in wb.namenode.files() {
        for b in &meta.blocks {
            assert!(
                !b.replicas.contains(&victim),
                "{name}: replica still on the drained node"
            );
            assert_eq!(b.replicas.len(), 3, "{name} under-replicated: {:?}", b.replicas);
        }
    }
}

/// Regression (review finding): a drain copy whose target crashes
/// mid-transfer is cancelled by the crash kill-switch — its completion
/// callback never runs — and the decommission used to stall forever in
/// the *decommissioning* state. The crash path now purges the dead
/// endpoint's in-flight drain entries and restarts the drain, which
/// completes (under-replicated if no target is left) instead of
/// hanging.
#[test]
fn drain_survives_its_target_crashing_mid_copy() {
    use amdahl_hadoop::faults::DecommissionSpec;
    let (mut e, w) = world(4, 3);
    {
        let mut wb = w.borrow_mut();
        wb.faults.replication = 2;
        let id = wb.namenode.alloc_block();
        wb.namenode.put_file(
            "f",
            FileMeta {
                blocks: vec![BlockMeta {
                    id,
                    size: 64.0 * MIB,
                    stored_size: 64.0 * MIB,
                    replicas: vec![NodeId(2), NodeId(3)],
                }],
            },
        );
    }
    // Node 2 drains at t=1; its only possible drain target is node 1,
    // which crashes shortly after the copy starts.
    let plan = InjectionPlan {
        decommissions: vec![DecommissionSpec { node: 2, at: 1.0 }],
        crashes: vec![CrashSpec { node: 1, at: 1.5 }],
        ..InjectionPlan::empty()
    };
    let sched = FaultSchedule::generate(&plan, 23, 4);
    faults::install(&mut e, &w, &sched);
    e.run();
    let wb = w.borrow();
    let stats = &wb.faults.stats;
    assert_eq!(stats.decommissions, 1);
    assert_eq!(stats.crashes, 1);
    assert!(
        !wb.namenode.is_decommissioning(NodeId(2)),
        "the drain must complete, not stall: {stats:?}"
    );
    assert!(!wb.faults.is_up(NodeId(2)), "drained node ends dead");
    assert!(wb.faults.is_up(NodeId(3)));
    // Whatever the exact crash/commit interleaving, the block survives
    // on node 3 (possibly under-replicated — both its peers are gone).
    let b = &wb.namenode.get_file("f").unwrap().blocks[0];
    assert!(b.replicas.contains(&NodeId(3)), "{:?}", b.replicas);
    assert!(!b.replicas.contains(&NodeId(2)) && !b.replicas.contains(&NodeId(1)));
}

fn churn_grid(seed: u64) -> SweepGrid {
    SweepGrid {
        families: vec![ClusterFamily::Amdahl],
        nodes: vec![5],
        cores: vec![2],
        write_paths: vec![WritePath::DirectIo],
        lzo: vec![false],
        workloads: vec![Workload::DfsioWrite],
        mtbf: vec![None, Some(40.0)],
        rejoin: vec![None, Some(30.0)],
        balancer: vec![None, Some(0.1)],
        ..SweepGrid::paper_default(seed, 1, 1)
    }
}

fn churn_opts(threads: usize, solver: SolverMode) -> SweepOptions {
    SweepOptions {
        threads,
        solver,
        dfsio_bytes_per_worker: 32.0 * MIB,
        dfsio_workers: 2,
        balancer_bandwidth_bps: 8.0 * MIB,
        ..SweepOptions::default()
    }
}

/// Satellite pin: re-join + balancer runs are byte-identical across
/// `--threads` values.
#[test]
fn churn_sweep_is_thread_count_independent() {
    let g = churn_grid(42);
    let a = run_sweep(&g, &churn_opts(1, SolverMode::Incremental)).to_json();
    let b = run_sweep(&g, &churn_opts(4, SolverMode::Incremental)).to_json();
    assert_eq!(a, b, "churn sweep output depends on --threads");
    assert!(a.contains("\"rejoin_delay\""), "churn records must carry the axis");
    assert!(a.contains("\"balancer_threshold\""));
}

/// Satellite pin: re-join + balancer runs are byte-identical across
/// both solver modes (the incremental engine's equivalence extends to
/// lifecycle churn).
#[test]
fn churn_sweep_is_solver_mode_identical() {
    let g = churn_grid(42);
    let whole = run_sweep(&g, &churn_opts(2, SolverMode::WholeSet));
    let inc = run_sweep(&g, &churn_opts(2, SolverMode::Incremental));
    assert_eq!(
        whole.sim_json(),
        inc.sim_json(),
        "solver modes diverged under lifecycle churn"
    );
    // The churn frontier pairs every churning scenario with its twin.
    let churn = inc.churn_frontier();
    assert!(!churn.is_empty());
    for row in &churn {
        assert!(row.baseline_mbps > 0.0, "{}: no fault-free twin", row.id);
    }
    let rendered = amdahl_hadoop::report::render_churn(&churn);
    assert!(rendered.contains("churn-vs-throughput frontier"));
}

/// The zero-churn invariant, end to end: a grid whose lifecycle axes
/// sit at their defaults emits byte-identical `BENCH_sweep.json` to a
/// grid that never heard of them, and no lifecycle key leaks into
/// fault-free records.
#[test]
fn zero_churn_plan_keeps_sweep_json_byte_identical() {
    let base = SweepGrid {
        families: vec![ClusterFamily::Amdahl],
        nodes: vec![5],
        cores: vec![1],
        write_paths: vec![WritePath::DirectIo],
        lzo: vec![false],
        workloads: vec![Workload::DfsioWrite],
        ..SweepGrid::paper_default(42, 1, 1)
    };
    let lifecycle_defaults = SweepGrid {
        decommission_at: vec![None],
        rejoin: vec![None],
        balancer: vec![None],
        ..base.clone()
    };
    let opts = SweepOptions {
        threads: 2,
        dfsio_bytes_per_worker: 32.0 * MIB,
        dfsio_workers: 2,
        ..SweepOptions::default()
    };
    let a = run_sweep(&base, &opts).to_json();
    let b = run_sweep(&lifecycle_defaults, &opts).to_json();
    assert_eq!(a, b, "explicit default lifecycle axes changed the bytes");
    for key in ["rejoin", "balancer", "decommission", "recommission", "balance_joules"] {
        assert!(!a.contains(key), "fault-free JSON leaked key {key:?}");
    }
    assert!(a.contains("\"id\": \"amdahl-n5-c1-direct-nolzo-dfsio-write\""));
}

/// The decommission axis runs end to end through the sweep: the
/// scenario drains the highest slave mid-run, serializes its axis and
/// counters, and the fault-free twin pairs in the degraded table.
#[test]
fn decommission_axis_sweeps_end_to_end() {
    let g = SweepGrid {
        families: vec![ClusterFamily::Amdahl],
        nodes: vec![5],
        cores: vec![2],
        write_paths: vec![WritePath::DirectIo],
        lzo: vec![false],
        workloads: vec![Workload::DfsioWrite],
        decommission_at: vec![None, Some(2.0)],
        ..SweepGrid::paper_default(21, 1, 1)
    };
    let r = run_sweep(&g, &churn_opts(2, SolverMode::Incremental));
    assert_eq!(r.records.len(), 2);
    let drained = r.records.iter().find(|x| x.decommission_at.is_some()).unwrap();
    assert!(drained.id.ends_with("-decomm2"), "id {}", drained.id);
    let f = drained.faults.as_ref().unwrap();
    assert_eq!(f.decommissions, 1, "{f:?}");
    assert_eq!(f.blocks_lost, 0, "graceful drains lose nothing: {f:?}");
    let json = r.to_json();
    assert!(json.contains("\"decommission_at\": 2.000000"));
    assert!(json.contains("\"decommissions\": 1"));
}
