//! Property-style invariants (seeded randomized generation; proptest is
//! unavailable offline, so cases are driven by `sim::Rng` sweeps).

use amdahl_hadoop::compress;
use amdahl_hadoop::sim::engine::shared;
use amdahl_hadoop::sim::{Engine, FlowSpec, Rng};

/// Engine invariant: with random flows over random resources, (a) time
/// never goes backwards, (b) per-resource usage never exceeds capacity
/// integral, (c) total delivered work equals what was requested.
#[test]
fn engine_conservation_random_flows() {
    for seed in 0..20u64 {
        let mut rng = Rng::new(seed);
        let mut e = Engine::new(seed);
        let n_res = 2 + rng.below(6) as usize;
        let res: Vec<_> = (0..n_res)
            .map(|i| e.add_resource(&format!("r{i}"), 1.0 + rng.f64() * 99.0))
            .collect();
        let cls = e.class("w");
        let n_flows = 5 + rng.below(40) as usize;
        let requested = shared(0.0f64);
        let delivered = shared(0.0f64);
        for _ in 0..n_flows {
            let total = 1.0 + rng.f64() * 500.0;
            *requested.borrow_mut() += total;
            let mut spec = FlowSpec::new(total, "f");
            let k = 1 + rng.below(3) as usize;
            for _ in 0..k {
                spec = spec.demand(res[rng.below(n_res as u64) as usize], 0.1 + rng.f64(), cls);
            }
            let d = delivered.clone();
            let start = rng.f64() * 10.0;
            e.after(start, move |e| {
                e.start_flow(spec, move |_| *d.borrow_mut() += total);
            });
        }
        e.run();
        assert!((*delivered.borrow() - *requested.borrow()).abs() < 1e-6 * *requested.borrow());
        for &r in &res {
            let res = e.resource(r);
            assert!(
                res.busy_integral <= res.capacity_integral * (1.0 + 1e-9),
                "seed {seed}: overcommitted resource"
            );
        }
    }
}

/// Solver invariant: the incremental component-partitioned solver and
/// the whole-set baseline produce bit-identical completion times on
/// random flow churn (random resources, demands, caps, start times).
/// Settle points are rate-change points in both modes, so even the
/// floating-point trajectories must coincide exactly.
#[test]
fn solver_modes_agree_on_random_flow_churn() {
    use amdahl_hadoop::sim::SolverMode;
    fn run(seed: u64, mode: SolverMode) -> Vec<u64> {
        let mut rng = Rng::new(seed ^ 0xABCD);
        let mut e = Engine::with_mode(seed, mode);
        let n_res = 2 + rng.below(6) as usize;
        let res: Vec<_> = (0..n_res)
            .map(|i| e.add_resource(&format!("r{i}"), 1.0 + rng.f64() * 99.0))
            .collect();
        let cls = e.class("w");
        let log = shared(Vec::<u64>::new());
        let n_flows = 5 + rng.below(40) as usize;
        for _ in 0..n_flows {
            let total = 1.0 + rng.f64() * 500.0;
            let mut spec = FlowSpec::new(total, "f");
            let k = 1 + rng.below(3) as usize;
            for _ in 0..k {
                spec = spec.demand(res[rng.below(n_res as u64) as usize], 0.1 + rng.f64(), cls);
            }
            if rng.f64() < 0.3 {
                spec = spec.cap(0.5 + rng.f64() * 50.0);
            }
            let l = log.clone();
            let start = rng.f64() * 10.0;
            e.after(start, move |e| {
                e.start_flow(spec, move |e| l.borrow_mut().push(e.now().to_bits()));
            });
        }
        e.run();
        let v = log.borrow().clone();
        v
    }
    for seed in 0..15u64 {
        assert_eq!(
            run(seed, SolverMode::WholeSet),
            run(seed, SolverMode::Incremental),
            "solver modes diverged at seed {seed}"
        );
    }
}

/// Codec invariant: decompress ∘ compress = identity on arbitrary bytes.
#[test]
fn codec_roundtrip_random() {
    let mut rng = Rng::new(77);
    for case in 0..200 {
        let len = rng.below(8192) as usize;
        let data: Vec<u8> = match case % 4 {
            0 => (0..len).map(|_| rng.below(256) as u8).collect(),
            1 => (0..len).map(|_| rng.below(3) as u8).collect(),
            2 => (0..len).map(|i| (i % 251) as u8).collect(),
            _ => compress::synthetic_pair_records(len / 24 + 1, case as u64),
        };
        let c = compress::compress(&data);
        assert_eq!(compress::decompress(&c).unwrap(), data, "case {case} len {len}");
    }
}

/// Zones invariant: kernel pair counts equal CPU brute force on random
/// catalog blocks (the end-to-end correctness anchor).
#[test]
fn zones_pairs_match_brute_force_random_blocks() {
    use amdahl_hadoop::runtime::{arcsec_sq, PairKernels};
    use amdahl_hadoop::zones::Catalog;
    let Ok(k) = PairKernels::load_default() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let arc = std::f64::consts::PI / 180.0 / 3600.0;
    for seed in 0..5u64 {
        let cat = Catalog::generate(seed, 0.0004, 60.0 * arc, 10.0);
        let mut rng = Rng::new(seed);
        let bi = rng.below(cat.grid as u64) as usize;
        let bj = rng.below(cat.grid as u64) as usize;
        let objs = cat.block_local(bi, bj, bi as f64 * cat.block, bj as f64 * cat.block);
        if objs.is_empty() {
            continue;
        }
        let t2 = arcsec_sq(60.0);
        let (rows, total) = k.pair_count(&objs, &objs, t2).unwrap();
        let mut brute = 0i64;
        for a in &objs {
            for b in &objs {
                let du = a[0] - b[0];
                let dv = a[1] - b[1];
                if du * du + dv * dv <= t2 {
                    brute += 1;
                }
            }
        }
        assert_eq!(total, brute, "seed {seed} block ({bi},{bj})");
        assert_eq!(rows.iter().map(|&r| r as i64).sum::<i64>(), total);
    }
}

/// HDFS invariant: whatever replication/flags, committed metadata is
/// self-consistent (sizes sum, replicas distinct and on datanodes).
#[test]
fn hdfs_metadata_consistent_random_configs() {
    use amdahl_hadoop::cluster::{Cluster, NodeId};
    use amdahl_hadoop::conf::HadoopConf;
    use amdahl_hadoop::hdfs::{write_file, World};
    use amdahl_hadoop::hw::{amdahl_blade, DiskKind, MIB};
    let mut rng = Rng::new(5);
    for case in 0..10 {
        let mut e = Engine::new(case);
        let cluster = Cluster::build(&mut e, &amdahl_blade(DiskKind::Raid0), 9);
        let mut world = World::new(cluster);
        world.namenode.set_datanodes((1..9).map(NodeId).collect());
        let world = shared(world);
        let conf = HadoopConf {
            dfs_replication: 1 + rng.below(3) as usize,
            direct_io_write: rng.f64() < 0.5,
            lzo_output: rng.f64() < 0.5,
            buffered_output: rng.f64() < 0.5,
            ..Default::default()
        };
        let bytes = (16.0 + rng.f64() * 300.0) * MIB;
        let client = NodeId(1 + rng.below(8) as usize);
        let conf2 = conf.clone();
        write_file(&mut e, &world, client, "f", bytes, &conf2, "hdfs-write", |_| {});
        e.run();
        let w = world.borrow();
        let f = w.namenode.get_file("f").unwrap();
        assert!((f.size() - bytes).abs() < 1.0, "case {case}");
        for b in &f.blocks {
            assert_eq!(b.replicas.len(), conf.dfs_replication);
            let mut s = b.replicas.clone();
            s.sort();
            s.dedup();
            assert_eq!(s.len(), conf.dfs_replication);
            assert_eq!(b.replicas[0], client, "first replica local");
            if conf.lzo_output {
                assert!(b.stored_size < b.size);
            }
        }
    }
}
