//! Property-style invariants (seeded randomized generation; proptest is
//! unavailable offline, so cases are driven by `sim::Rng` sweeps).

use amdahl_hadoop::compress;
use amdahl_hadoop::sim::engine::shared;
use amdahl_hadoop::sim::{Engine, FlowSpec, Rng};

/// Engine invariant: with random flows over random resources, (a) time
/// never goes backwards, (b) per-resource usage never exceeds capacity
/// integral, (c) total delivered work equals what was requested.
#[test]
fn engine_conservation_random_flows() {
    for seed in 0..20u64 {
        let mut rng = Rng::new(seed);
        let mut e = Engine::new(seed);
        let n_res = 2 + rng.below(6) as usize;
        let res: Vec<_> = (0..n_res)
            .map(|i| e.add_resource(&format!("r{i}"), 1.0 + rng.f64() * 99.0))
            .collect();
        let cls = e.class("w");
        let n_flows = 5 + rng.below(40) as usize;
        let requested = shared(0.0f64);
        let delivered = shared(0.0f64);
        for _ in 0..n_flows {
            let total = 1.0 + rng.f64() * 500.0;
            *requested.borrow_mut() += total;
            let mut spec = FlowSpec::new(total, "f");
            let k = 1 + rng.below(3) as usize;
            for _ in 0..k {
                spec = spec.demand(res[rng.below(n_res as u64) as usize], 0.1 + rng.f64(), cls);
            }
            let d = delivered.clone();
            let start = rng.f64() * 10.0;
            e.after(start, move |e| {
                e.start_flow(spec, move |_| *d.borrow_mut() += total);
            });
        }
        e.run();
        assert!((*delivered.borrow() - *requested.borrow()).abs() < 1e-6 * *requested.borrow());
        for &r in &res {
            let res = e.resource(r);
            assert!(
                res.busy_integral <= res.capacity_integral * (1.0 + 1e-9),
                "seed {seed}: overcommitted resource"
            );
        }
    }
}

/// Solver invariant: the incremental component-partitioned solver and
/// the whole-set baseline produce bit-identical completion times on
/// random flow churn (random resources, demands, caps, start times).
/// Settle points are rate-change points in both modes, so even the
/// floating-point trajectories must coincide exactly.
#[test]
fn solver_modes_agree_on_random_flow_churn() {
    use amdahl_hadoop::sim::SolverMode;
    fn run(seed: u64, mode: SolverMode) -> Vec<u64> {
        let mut rng = Rng::new(seed ^ 0xABCD);
        let mut e = Engine::with_mode(seed, mode);
        let n_res = 2 + rng.below(6) as usize;
        let res: Vec<_> = (0..n_res)
            .map(|i| e.add_resource(&format!("r{i}"), 1.0 + rng.f64() * 99.0))
            .collect();
        let cls = e.class("w");
        let log = shared(Vec::<u64>::new());
        let n_flows = 5 + rng.below(40) as usize;
        for _ in 0..n_flows {
            let total = 1.0 + rng.f64() * 500.0;
            let mut spec = FlowSpec::new(total, "f");
            let k = 1 + rng.below(3) as usize;
            for _ in 0..k {
                spec = spec.demand(res[rng.below(n_res as u64) as usize], 0.1 + rng.f64(), cls);
            }
            if rng.f64() < 0.3 {
                spec = spec.cap(0.5 + rng.f64() * 50.0);
            }
            let l = log.clone();
            let start = rng.f64() * 10.0;
            e.after(start, move |e| {
                e.start_flow(spec, move |e| l.borrow_mut().push(e.now().to_bits()));
            });
        }
        e.run();
        let v = log.borrow().clone();
        v
    }
    for seed in 0..15u64 {
        assert_eq!(
            run(seed, SolverMode::WholeSet),
            run(seed, SolverMode::Incremental),
            "solver modes diverged at seed {seed}"
        );
    }
}

/// Codec invariant: decompress ∘ compress = identity on arbitrary bytes.
#[test]
fn codec_roundtrip_random() {
    let mut rng = Rng::new(77);
    for case in 0..200 {
        let len = rng.below(8192) as usize;
        let data: Vec<u8> = match case % 4 {
            0 => (0..len).map(|_| rng.below(256) as u8).collect(),
            1 => (0..len).map(|_| rng.below(3) as u8).collect(),
            2 => (0..len).map(|i| (i % 251) as u8).collect(),
            _ => compress::synthetic_pair_records(len / 24 + 1, case as u64),
        };
        let c = compress::compress(&data);
        assert_eq!(compress::decompress(&c).unwrap(), data, "case {case} len {len}");
    }
}

/// Zones invariant: kernel pair counts equal CPU brute force on random
/// catalog blocks (the end-to-end correctness anchor).
#[test]
fn zones_pairs_match_brute_force_random_blocks() {
    use amdahl_hadoop::runtime::{arcsec_sq, PairKernels};
    use amdahl_hadoop::zones::Catalog;
    let Ok(k) = PairKernels::load_default() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let arc = std::f64::consts::PI / 180.0 / 3600.0;
    for seed in 0..5u64 {
        let cat = Catalog::generate(seed, 0.0004, 60.0 * arc, 10.0);
        let mut rng = Rng::new(seed);
        let bi = rng.below(cat.grid as u64) as usize;
        let bj = rng.below(cat.grid as u64) as usize;
        let objs = cat.block_local(bi, bj, bi as f64 * cat.block, bj as f64 * cat.block);
        if objs.is_empty() {
            continue;
        }
        let t2 = arcsec_sq(60.0);
        let (rows, total) = k.pair_count(&objs, &objs, t2).unwrap();
        let mut brute = 0i64;
        for a in &objs {
            for b in &objs {
                let du = a[0] - b[0];
                let dv = a[1] - b[1];
                if du * du + dv * dv <= t2 {
                    brute += 1;
                }
            }
        }
        assert_eq!(total, brute, "seed {seed} block ({bi},{bj})");
        assert_eq!(rows.iter().map(|&r| r as i64).sum::<i64>(), total);
    }
}

/// HDFS invariant: whatever replication/flags, committed metadata is
/// self-consistent (sizes sum, replicas distinct and on datanodes).
#[test]
fn hdfs_metadata_consistent_random_configs() {
    use amdahl_hadoop::cluster::{Cluster, NodeId};
    use amdahl_hadoop::conf::HadoopConf;
    use amdahl_hadoop::hdfs::{write_file, World};
    use amdahl_hadoop::hw::{amdahl_blade, DiskKind, MIB};
    let mut rng = Rng::new(5);
    for case in 0..10 {
        let mut e = Engine::new(case);
        let cluster = Cluster::build(&mut e, &amdahl_blade(DiskKind::Raid0), 9);
        let mut world = World::new(cluster);
        world.namenode.set_datanodes((1..9).map(NodeId).collect());
        let world = shared(world);
        let conf = HadoopConf {
            dfs_replication: 1 + rng.below(3) as usize,
            direct_io_write: rng.f64() < 0.5,
            lzo_output: rng.f64() < 0.5,
            buffered_output: rng.f64() < 0.5,
            ..Default::default()
        };
        let bytes = (16.0 + rng.f64() * 300.0) * MIB;
        let client = NodeId(1 + rng.below(8) as usize);
        let conf2 = conf.clone();
        write_file(&mut e, &world, client, "f", bytes, &conf2, "hdfs-write", |_| {});
        e.run();
        let w = world.borrow();
        let f = w.namenode.get_file("f").unwrap();
        assert!((f.size() - bytes).abs() < 1.0, "case {case}");
        for b in &f.blocks {
            assert_eq!(b.replicas.len(), conf.dfs_replication);
            let mut s = b.replicas.clone();
            s.sort();
            s.dedup();
            assert_eq!(s.len(), conf.dfs_replication);
            assert_eq!(b.replicas[0], client, "first replica local");
            if conf.lzo_output {
                assert!(b.stored_size < b.size);
            }
        }
    }
}

/// Stream-scheduler safety: the pool is never overcommitted, and under
/// fair-share admission no tenant exceeds its quota while every other
/// tenant still has pending work (lending is only legal against idle
/// queues). After each admission fixed point, no admissible head job is
/// left waiting — the no-starvation-with-free-slots property.
#[test]
fn stream_scheduler_quota_and_pool_invariants_random() {
    use amdahl_hadoop::stream::{QueuedJob, SchedPolicy, StreamScheduler};
    use std::collections::VecDeque;
    for seed in 0..20u64 {
        let mut rng = Rng::new(seed ^ 0x5EED);
        let policy = if seed % 2 == 0 { SchedPolicy::Fair } else { SchedPolicy::Fifo };
        let capacity = 4 + rng.below(29) as usize;
        let n_tenants = 2 + rng.below(4) as usize;
        let quotas: Vec<usize> =
            (0..n_tenants).map(|_| 1 + rng.below(capacity as u64 / 2 + 1) as usize).collect();
        let mut s = StreamScheduler::new(policy, capacity, quotas);
        let mut seq = 0usize;
        let mut mirror: VecDeque<QueuedJob> = VecDeque::new(); // FIFO arrival order
        let mut running: VecDeque<QueuedJob> = VecDeque::new();
        for _step in 0..60 {
            // Top every queue up past the pool size: one admission batch
            // can never drain a queue, so every admission this run is
            // made under contention and the quota rule must bind.
            for t in 0..n_tenants {
                while s.pending(t) <= capacity {
                    let job = QueuedJob {
                        seq,
                        tenant: t,
                        demand: 1 + rng.below(capacity as u64) as usize,
                        enqueued_at: 0.0,
                    };
                    s.enqueue(job);
                    mirror.push_back(job);
                    seq += 1;
                }
            }
            for j in s.admit() {
                running.push_back(j);
            }
            let used: usize = (0..n_tenants).map(|t| s.running_slots(t)).sum();
            assert!(used <= s.capacity(), "seed {seed}: pool overcommitted");
            assert_eq!(s.free_slots(), s.capacity() - used);
            match policy {
                SchedPolicy::Fair => {
                    // Under contention a tenant can only exceed its
                    // quota through the single idle-pool liveness
                    // admission — never two tenants at once.
                    let over: Vec<usize> =
                        (0..n_tenants).filter(|&t| s.running_slots(t) > s.quota(t)).collect();
                    assert!(
                        over.len() <= 1,
                        "seed {seed}: tenants {over:?} over quota with peers pending"
                    );
                    for t in 0..n_tenants {
                        // Fixed point: a head that fits both pool and
                        // quota must not be left waiting.
                        if let Some(d) = s.head_demand(t) {
                            let fits = d <= s.free_slots()
                                && s.running_slots(t) + d <= s.quota(t);
                            assert!(!fits, "seed {seed}: admissible head starved");
                        }
                    }
                }
                SchedPolicy::Fifo => {
                    mirror.retain(|j| !running.iter().any(|r| r.seq == j.seq));
                    if let Some(head) = mirror.front() {
                        assert!(
                            head.demand.min(capacity) > s.free_slots(),
                            "seed {seed}: FIFO head fits but was not admitted"
                        );
                    }
                }
            }
            // Drain roughly half the running set to churn the pool.
            for _ in 0..(running.len() / 2) {
                let j = running.pop_front().expect("non-empty");
                s.complete(j.tenant, j.demand);
            }
        }
    }
}

/// Stream-scheduler liveness: any finite workload fully drains under
/// both policies — admissions plus completions always make progress,
/// so no job is starved forever and the slot ledger returns to empty.
#[test]
fn stream_scheduler_drains_any_finite_workload() {
    use amdahl_hadoop::stream::{QueuedJob, SchedPolicy, StreamScheduler};
    use std::collections::VecDeque;
    for seed in 0..30u64 {
        let mut rng = Rng::new(seed ^ 0xD7A1);
        for policy in [SchedPolicy::Fifo, SchedPolicy::Fair] {
            let capacity = 2 + rng.below(20) as usize;
            let n_tenants = 1 + rng.below(5) as usize;
            let quotas: Vec<usize> =
                (0..n_tenants).map(|_| rng.below(capacity as u64 + 1) as usize).collect();
            let mut s = StreamScheduler::new(policy, capacity, quotas);
            let n_jobs = 1 + rng.below(60) as usize;
            for seq in 0..n_jobs {
                s.enqueue(QueuedJob {
                    seq,
                    tenant: rng.below(n_tenants as u64) as usize,
                    demand: 1 + rng.below(capacity as u64 + 4) as usize,
                    enqueued_at: 0.0,
                });
            }
            let mut running: VecDeque<QueuedJob> = VecDeque::new();
            let mut guard = 0;
            while s.completed() < s.submitted() {
                guard += 1;
                assert!(guard < 10_000, "seed {seed} {policy:?}: no convergence");
                for j in s.admit() {
                    running.push_back(j);
                }
                let j = running
                    .pop_front()
                    .unwrap_or_else(|| panic!("seed {seed} {policy:?}: deadlock"));
                s.complete(j.tenant, j.demand);
            }
            assert_eq!(s.pending_total(), 0);
            assert_eq!(s.free_slots(), s.capacity(), "slot ledger must drain to empty");
        }
    }
}

/// Arrival-stream invariant: the schedule is a pure function of the
/// `(base seed, scenario stable id)` pair — regenerating with the same
/// pair reproduces every byte, while different ids or seeds decorrelate
/// — and every draw respects the tenant population and horizon.
#[test]
fn stream_arrivals_reproducible_from_seed_and_id() {
    use amdahl_hadoop::stream::{
        arrival_stream_seed, ArrivalConfig, ArrivalSchedule, TenantSet,
    };
    let ids = [
        "amdahl-n9-c2-direct-nolzo-search-arr6-ten2",
        "amdahl-n9-c4-buffered-lzo-search-arr12-ten3-fair",
        "occ-n9-c1-direct-nolzo-search-arr2-ten2",
    ];
    for seed in [7u64, 42, 12345] {
        for id in ids {
            for n in [2usize, 3, 5] {
                let cfg =
                    ArrivalConfig { rate_per_min: 9.0, horizon_s: 240.0, ..Default::default() };
                let a = ArrivalSchedule::generate(
                    &cfg,
                    &TenantSet::generate(n),
                    arrival_stream_seed(seed, id),
                );
                let b = ArrivalSchedule::generate(
                    &cfg,
                    &TenantSet::generate(n),
                    arrival_stream_seed(seed, id),
                );
                assert_eq!(a.arrivals, b.arrivals, "same (seed, id) must reproduce");
                for w in a.arrivals.windows(2) {
                    assert!(w[0].at <= w[1].at);
                }
                for arr in &a.arrivals {
                    assert!(arr.tenant < n && arr.at >= 0.0 && arr.at < cfg.horizon_s);
                }
            }
        }
    }
    let cfg = ArrivalConfig::default();
    let ts = TenantSet::generate(2);
    let base = ArrivalSchedule::generate(&cfg, &ts, arrival_stream_seed(42, ids[0]));
    assert!(!base.arrivals.is_empty());
    let other_id = ArrivalSchedule::generate(&cfg, &ts, arrival_stream_seed(42, ids[1]));
    assert_ne!(base.arrivals, other_id.arrivals, "ids must decorrelate");
    let other_seed = ArrivalSchedule::generate(&cfg, &ts, arrival_stream_seed(43, ids[0]));
    assert_ne!(base.arrivals, other_seed.arrivals, "seeds must decorrelate");
}
