//! Integration: MapReduce engine over the simulated cluster.

use std::cell::RefCell;
use std::rc::Rc;

use amdahl_hadoop::cluster::{Cluster, NodeId};
use amdahl_hadoop::conf::HadoopConf;
use amdahl_hadoop::hdfs::testdfsio::preplace_file;
use amdahl_hadoop::hdfs::World;
use amdahl_hadoop::hw::{amdahl_blade, DiskKind, MIB};
use amdahl_hadoop::mapreduce::{run_job, JobSpec, MapFn, MapOutput, ReduceFn, ReduceOutput, SplitMeta};
use amdahl_hadoop::sim::engine::shared;
use amdahl_hadoop::sim::Engine;

struct Ident(f64);
impl MapFn for Ident {
    fn run(&self, s: &SplitMeta) -> MapOutput {
        MapOutput { bytes: s.bytes * self.0, records: s.records, app_cpu: 0.02 }
    }
}
struct Sink;
impl ReduceFn for Sink {
    fn run(&mut self, i: &amdahl_hadoop::mapreduce::tasks::ReduceInput) -> ReduceOutput {
        ReduceOutput { hdfs_bytes: i.bytes * 0.5, app_cpu: 0.05 }
    }
}

fn setup(seed: u64, parts: usize) -> (Engine, amdahl_hadoop::hdfs::WorldHandle, Vec<String>) {
    let mut e = Engine::new(seed);
    let cluster = Cluster::build(&mut e, &amdahl_blade(DiskKind::Raid0), 9);
    let mut world = World::new(cluster);
    world.namenode.set_datanodes((1..9).map(NodeId).collect());
    let world = shared(world);
    let mut rng = e.rng.fork(3);
    let conf = HadoopConf::default();
    let files: Vec<String> = (0..parts)
        .map(|i| {
            let name = format!("in/p{i}");
            preplace_file(&world, &mut rng, &name, NodeId(1 + i % 8), 64.0 * MIB, &conf);
            name
        })
        .collect();
    (e, world, files)
}

fn job(files: Vec<String>, conf: HadoopConf, n_red: usize) -> JobSpec {
    JobSpec {
        name: "t".into(),
        input_files: files,
        map: Rc::new(Ident(1.1)),
        reduce: Rc::new(RefCell::new(Sink)),
        n_reducers: n_red,
        conf,
        map_class: "mapper".into(),
        reduce_class: "reducer-search".into(),
        output_prefix: "out".into(),
        partition: JobSpec::uniform_partition(n_red),
        reduce_records_per_byte: 1.0 / 63.0,
    }
}

#[test]
fn byte_conservation_through_shuffle() {
    let (mut e, w, files) = setup(1, 16);
    let res = shared(None);
    let r = res.clone();
    run_job(&mut e, &w, job(files, HadoopConf::default(), 8), move |_, j| *r.borrow_mut() = Some(j));
    e.run();
    let j = res.borrow().clone().unwrap();
    assert!((j.input_bytes - 16.0 * 64.0 * MIB).abs() < 1.0);
    assert!((j.map_output_bytes - j.input_bytes * 1.1).abs() / j.map_output_bytes < 1e-9);
    assert!((j.hdfs_output_bytes - j.map_output_bytes * 0.5).abs() / j.hdfs_output_bytes < 1e-6);
}

#[test]
fn reducer_waves() {
    // 16 reducers of fixed work on 16 slots (one wave) vs on 8 slots
    // (two waves): halving `mapred.tasktracker.reduce.tasks.maximum`
    // must stretch the reduce phase.
    let (mut e1, w1, f1) = setup(2, 16);
    let res1 = shared(None);
    let r = res1.clone();
    let two_slots = HadoopConf { reduce_slots: 2, ..Default::default() };
    run_job(&mut e1, &w1, job(f1, two_slots, 16), move |_, j| *r.borrow_mut() = Some(j));
    e1.run();
    let (mut e2, w2, f2) = setup(2, 16);
    let res2 = shared(None);
    let r = res2.clone();
    let one_slot = HadoopConf { reduce_slots: 1, ..Default::default() };
    run_job(&mut e2, &w2, job(f2, one_slot, 16), move |_, j| *r.borrow_mut() = Some(j));
    e2.run();
    let one_wave = res1.borrow().clone().unwrap().reduce_phase;
    let two_waves = res2.borrow().clone().unwrap().reduce_phase;
    assert!(
        two_waves > one_wave * 1.2,
        "two waves {two_waves:.1}s vs one wave {one_wave:.1}s"
    );
}

#[test]
fn small_sort_buffer_slows_maps() {
    // io.sort.mb 16 forces multi-spill + merge (§3.1's motivation).
    let (mut e1, w1, f1) = setup(3, 8);
    let res1 = shared(None);
    let r = res1.clone();
    run_job(&mut e1, &w1, job(f1, HadoopConf::default(), 8), move |_, j| *r.borrow_mut() = Some(j));
    e1.run();
    let (mut e2, w2, f2) = setup(3, 8);
    let res2 = shared(None);
    let r = res2.clone();
    run_job(
        &mut e2,
        &w2,
        job(f2, HadoopConf { io_sort_mb: 16, ..Default::default() }, 8),
        move |_, j| *r.borrow_mut() = Some(j),
    );
    e2.run();
    let tuned = res1.borrow().clone().unwrap().map_phase;
    let small = res2.borrow().clone().unwrap().map_phase;
    assert!(small > tuned * 1.05, "multi-spill {small:.1}s vs single-spill {tuned:.1}s");
}

#[test]
fn deterministic_across_runs() {
    let run = |seed| {
        let (mut e, w, f) = setup(seed, 8);
        let res = shared(None);
        let r = res.clone();
        run_job(&mut e, &w, job(f, HadoopConf::default(), 4), move |_, j| *r.borrow_mut() = Some(j));
        e.run();
        let j = res.borrow().clone().unwrap();
        (j.duration, j.map_phase, j.reduce_phase)
    };
    assert_eq!(run(9), run(9), "same seed must be bit-identical");
    // (different seeds may legitimately coincide in makespan; only
    // same-seed equality is an invariant)
}
