//! Integration tests for the observability subsystem: trace / metrics
//! determinism across solver modes and thread counts, the zero-cost
//! guarantee when obs is off, and the §4 family CPU attribution shapes
//! (where do the Atom's cycles go).

use amdahl_hadoop::conf::{ClusterPreset, HadoopConf};
use amdahl_hadoop::hdfs::testdfsio;
use amdahl_hadoop::hw::MIB;
use amdahl_hadoop::obs::{ObsReport, FAMILIES};
use amdahl_hadoop::sim::{ObsSpec, SimConfig, SolverMode};
use amdahl_hadoop::sweep::{
    run_sweep, ClusterFamily, SweepGrid, SweepOptions, Workload, WritePath,
};
use amdahl_hadoop::zones::{run_app, App, ZonesConfig};

fn dfsio_obs(solver: SolverMode) -> ObsReport {
    let conf = HadoopConf::default();
    let sim = SimConfig::new(42).with_solver(solver).with_obs(ObsSpec::full(5.0));
    let run = testdfsio::write_test_on(ClusterPreset::Amdahl, sim, 2, 48.0 * MIB, &conf);
    run.obs.expect("obs was armed")
}

fn zones_obs(app: App, solver: SolverMode) -> (ObsReport, f64) {
    let conf = HadoopConf {
        buffered_output: true,
        direct_io_write: true,
        reduce_slots: if app == App::Stat { 3 } else { 2 },
        ..Default::default()
    };
    let z = ZonesConfig {
        seed: 17,
        scale: 0.0008,
        kernel_every: usize::MAX,
        kernels: None,
        solver,
        obs: ObsSpec::full(5.0),
        ..Default::default()
    };
    let out = run_app(ClusterPreset::Amdahl, &conf, &z, app);
    (out.obs.expect("obs was armed"), out.total_seconds)
}

/// The tentpole determinism bar: the trace and metrics exports are pure
/// functions of the scenario — byte-identical across both `SolverMode`s
/// (rates are bit-identical by the PR-2 refactor gate, and the obs layer
/// adds no RNG, no wall clock, and no hash-map iteration).
#[test]
fn trace_and_metrics_are_byte_identical_across_solver_modes() {
    let a = dfsio_obs(SolverMode::Incremental);
    let b = dfsio_obs(SolverMode::WholeSet);
    assert_eq!(a.trace_json, b.trace_json, "dfsio trace diverged across solver modes");
    assert_eq!(a.metrics_json, b.metrics_json, "dfsio metrics diverged across solver modes");

    let (za, ta) = zones_obs(App::Search, SolverMode::Incremental);
    let (zb, tb) = zones_obs(App::Search, SolverMode::WholeSet);
    assert_eq!(ta, tb, "search outcome diverged across solver modes");
    assert_eq!(za.trace_json, zb.trace_json, "search trace diverged across solver modes");
    assert_eq!(za.metrics_json, zb.metrics_json, "search metrics diverged across solver modes");
    assert_eq!(za.cpu_families, zb.cpu_families);
}

/// Per-scenario trace files written by a sweep are byte-identical across
/// worker thread counts (each scenario's engine lives entirely inside
/// one thread; records land in grid order).
#[test]
fn sweep_trace_files_are_byte_identical_across_thread_counts() {
    let g = SweepGrid {
        families: vec![ClusterFamily::Amdahl],
        nodes: vec![5],
        cores: vec![1, 2],
        write_paths: vec![WritePath::DirectIo],
        lzo: vec![false],
        workloads: vec![Workload::DfsioWrite, Workload::Search],
        ..SweepGrid::paper_default(42, 1, 1)
    };
    let dir = |tag: &str| {
        std::env::temp_dir().join(format!("amdahl-obs-int-{}-{tag}", std::process::id()))
    };
    let opts = |threads: usize, tag: &str| SweepOptions {
        threads,
        dfsio_bytes_per_worker: 32.0 * MIB,
        dfsio_workers: 2,
        obs: ObsSpec::full(10.0),
        trace_dir: Some(dir(tag).to_string_lossy().into_owned()),
        ..SweepOptions::default()
    };
    let r1 = run_sweep(&g, &opts(1, "t1"));
    let r4 = run_sweep(&g, &opts(4, "t4"));
    assert_eq!(r1.to_json(), r4.to_json(), "sweep JSON diverged across thread counts");
    for sc in g.expand() {
        for kind in ["trace", "metrics"] {
            let name = format!("{}.{kind}.json", sc.id);
            let a = std::fs::read(dir("t1").join(&name)).expect("threads=1 export missing");
            let b = std::fs::read(dir("t4").join(&name)).expect("threads=4 export missing");
            assert_eq!(a, b, "{name} diverged across thread counts");
        }
    }
    let _ = std::fs::remove_dir_all(dir("t1"));
    let _ = std::fs::remove_dir_all(dir("t4"));
}

/// Zero-cost-when-off: an obs-off sweep carries no obs artifacts — no
/// report, no `cpu_families` / `solve_ms` keys in the JSON — and turning
/// obs ON changes no simulation measurement.
#[test]
fn disabled_obs_is_invisible_and_enabling_it_changes_nothing() {
    let g = SweepGrid {
        families: vec![ClusterFamily::Amdahl],
        nodes: vec![5],
        cores: vec![2],
        write_paths: vec![WritePath::DirectIo],
        lzo: vec![false],
        workloads: vec![Workload::DfsioWrite, Workload::Search],
        ..SweepGrid::paper_default(7, 1, 1)
    };
    let base = SweepOptions {
        threads: 2,
        dfsio_bytes_per_worker: 32.0 * MIB,
        dfsio_workers: 2,
        ..SweepOptions::default()
    };
    let off = run_sweep(&g, &base);
    let json = off.to_json();
    assert!(!json.contains("cpu_families"), "obs-off JSON grew an obs key");
    assert!(!json.contains("solve_ms"), "wall clock leaked into default JSON");

    let on = run_sweep(&g, &SweepOptions { obs: ObsSpec::full(5.0), ..base });
    for (a, b) in off.records.iter().zip(on.records.iter()) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.seconds, b.seconds, "{}: obs changed simulated time", a.id);
        assert_eq!(a.bytes_moved, b.bytes_moved);
        assert_eq!(a.joules, b.joules, "{}: obs changed the energy model", a.id);
        assert!(!b.cpu_families.is_empty(), "{}: obs-on record lost attribution", b.id);
    }
}

/// `--perf-wallclock` puts `solve_ms` into the perf section (and only
/// there — `sim_json` has no perf section at all).
#[test]
fn perf_wallclock_flag_gates_solve_ms() {
    let g = SweepGrid {
        families: vec![ClusterFamily::Amdahl],
        nodes: vec![5],
        cores: vec![1],
        write_paths: vec![WritePath::DirectIo],
        lzo: vec![false],
        workloads: vec![Workload::DfsioWrite],
        ..SweepGrid::paper_default(7, 1, 1)
    };
    let opts = SweepOptions {
        threads: 1,
        dfsio_bytes_per_worker: 32.0 * MIB,
        dfsio_workers: 2,
        perf_wallclock: true,
        ..SweepOptions::default()
    };
    let r = run_sweep(&g, &opts);
    assert!(r.to_json().contains("\"solve_ms\""), "perf_wallclock did not emit solve_ms");
    assert!(!r.sim_json().contains("solve_ms"));
    assert!(
        r.records.iter().any(|x| x.stats.solve_ns > 0),
        "solver spent no measurable wall time"
    );
}

/// The trace export is a loadable Chrome trace document with the spans
/// the tentpole promises: job phases, map/reduce attempts, block
/// pipelines, shuffle fetches.
#[test]
fn search_trace_contains_the_promised_span_families() {
    let (obs, _) = zones_obs(App::Search, SolverMode::Incremental);
    let trace = obs.trace_json.expect("trace armed");
    assert!(trace.starts_with("{\"traceEvents\":[\n"));
    assert!(trace.ends_with("\n]}\n"));
    assert_eq!(trace.matches('{').count(), trace.matches('}').count());
    for needle in [
        "\"cat\":\"job\"",       // job span + phase instants
        "\"cat\":\"mapreduce\"", // map/reduce attempt spans
        "\"cat\":\"hdfs\"",      // block write/read pipeline spans
        "\"cat\":\"shuffle\"",   // reduce-side fetch spans
        "\"ph\":\"C\"",          // utilization counter samples
    ] {
        assert!(trace.contains(needle), "trace missing {needle}");
    }
    let metrics = obs.metrics_json.expect("metrics armed");
    for needle in ["hdfs.block_write_s", "shuffle.fetch_s", "mapreduce.map_attempt_s", "p95"] {
        assert!(metrics.contains(needle), "metrics missing {needle}");
    }
}

/// The §4 reproduction: on the Atom-class blade, a dfsio write burns its
/// cycles in the HDFS protocol family, not compute; the search app adds
/// shuffle and compute families on top.
#[test]
fn family_attribution_matches_the_workload_shape() {
    let idx = |name: &str| FAMILIES.iter().position(|f| *f == name).unwrap();
    let d = dfsio_obs(SolverMode::Incremental).cpu_families;
    assert_eq!(d.len(), FAMILIES.len());
    assert!(d[idx("hdfs")].cpu_core_seconds > 0.0, "dfsio write must burn hdfs CPU");
    assert!(d[idx("hdfs")].joules > 0.0);
    assert_eq!(d[idx("shuffle")].cpu_core_seconds, 0.0, "dfsio has no shuffle");
    assert!(
        d[idx("hdfs")].cpu_core_seconds > d[idx("compute")].cpu_core_seconds,
        "dfsio: protocol overhead must dominate compute"
    );

    let (s, _) = zones_obs(App::Search, SolverMode::Incremental);
    let s = s.cpu_families;
    assert!(s[idx("hdfs")].cpu_core_seconds > 0.0);
    assert!(s[idx("shuffle")].cpu_core_seconds > 0.0, "search shuffles its pairs");
    assert!(s[idx("compute")].cpu_core_seconds > 0.0, "search maps/sorts burn compute");
    assert_eq!(s[idx("balance")].cpu_core_seconds, 0.0, "no balancer ran");
}
