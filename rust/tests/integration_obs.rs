//! Integration tests for the observability subsystem: trace / metrics
//! determinism across solver modes and thread counts, the zero-cost
//! guarantee when obs is off, and the §4 family CPU attribution shapes
//! (where do the Atom's cycles go).

use amdahl_hadoop::conf::{ClusterPreset, HadoopConf};
use amdahl_hadoop::faults::{FaultEvent, FaultKind, FaultSchedule};
use amdahl_hadoop::hdfs::testdfsio;
use amdahl_hadoop::hw::{DiskKind, MIB};
use amdahl_hadoop::obs::{BottleneckReport, ObsReport, FAMILIES};
use amdahl_hadoop::sim::{ObsSpec, SimConfig, SolverMode};
use amdahl_hadoop::sweep::{
    run_sweep, ClusterFamily, SweepGrid, SweepOptions, Workload, WritePath,
};
use amdahl_hadoop::zones::{run_app, App, ZonesConfig};

fn dfsio_obs(solver: SolverMode) -> ObsReport {
    let conf = HadoopConf::default();
    let sim = SimConfig::new(42).with_solver(solver).with_obs(ObsSpec::full(5.0));
    let run = testdfsio::write_test_on(ClusterPreset::Amdahl, sim, 2, 48.0 * MIB, &conf);
    run.obs.expect("obs was armed")
}

fn zones_obs(app: App, solver: SolverMode) -> (ObsReport, f64) {
    let conf = HadoopConf {
        buffered_output: true,
        direct_io_write: true,
        reduce_slots: if app == App::Stat { 3 } else { 2 },
        ..Default::default()
    };
    let z = ZonesConfig {
        seed: 17,
        scale: 0.0008,
        kernel_every: usize::MAX,
        kernels: None,
        solver,
        obs: ObsSpec::full(5.0),
        ..Default::default()
    };
    let out = run_app(ClusterPreset::Amdahl, &conf, &z, app);
    (out.obs.expect("obs was armed"), out.total_seconds)
}

/// The tentpole determinism bar: the trace and metrics exports are pure
/// functions of the scenario — byte-identical across both `SolverMode`s
/// (rates are bit-identical by the PR-2 refactor gate, and the obs layer
/// adds no RNG, no wall clock, and no hash-map iteration).
#[test]
fn trace_and_metrics_are_byte_identical_across_solver_modes() {
    let a = dfsio_obs(SolverMode::Incremental);
    let b = dfsio_obs(SolverMode::WholeSet);
    assert_eq!(a.trace_json, b.trace_json, "dfsio trace diverged across solver modes");
    assert_eq!(a.metrics_json, b.metrics_json, "dfsio metrics diverged across solver modes");

    let (za, ta) = zones_obs(App::Search, SolverMode::Incremental);
    let (zb, tb) = zones_obs(App::Search, SolverMode::WholeSet);
    assert_eq!(ta, tb, "search outcome diverged across solver modes");
    assert_eq!(za.trace_json, zb.trace_json, "search trace diverged across solver modes");
    assert_eq!(za.metrics_json, zb.metrics_json, "search metrics diverged across solver modes");
    assert_eq!(za.cpu_families, zb.cpu_families);
}

/// Per-scenario trace files written by a sweep are byte-identical across
/// worker thread counts (each scenario's engine lives entirely inside
/// one thread; records land in grid order).
#[test]
fn sweep_trace_files_are_byte_identical_across_thread_counts() {
    let g = SweepGrid {
        families: vec![ClusterFamily::Amdahl],
        nodes: vec![5],
        cores: vec![1, 2],
        write_paths: vec![WritePath::DirectIo],
        lzo: vec![false],
        workloads: vec![Workload::DfsioWrite, Workload::Search],
        ..SweepGrid::paper_default(42, 1, 1)
    };
    let dir = |tag: &str| {
        std::env::temp_dir().join(format!("amdahl-obs-int-{}-{tag}", std::process::id()))
    };
    let opts = |threads: usize, tag: &str| SweepOptions {
        threads,
        dfsio_bytes_per_worker: 32.0 * MIB,
        dfsio_workers: 2,
        obs: ObsSpec::full(10.0),
        trace_dir: Some(dir(tag).to_string_lossy().into_owned()),
        ..SweepOptions::default()
    };
    let r1 = run_sweep(&g, &opts(1, "t1"));
    let r4 = run_sweep(&g, &opts(4, "t4"));
    assert_eq!(r1.to_json(), r4.to_json(), "sweep JSON diverged across thread counts");
    for sc in g.expand() {
        for kind in ["trace", "metrics"] {
            let name = format!("{}.{kind}.json", sc.id);
            let a = std::fs::read(dir("t1").join(&name)).expect("threads=1 export missing");
            let b = std::fs::read(dir("t4").join(&name)).expect("threads=4 export missing");
            assert_eq!(a, b, "{name} diverged across thread counts");
        }
    }
    let _ = std::fs::remove_dir_all(dir("t1"));
    let _ = std::fs::remove_dir_all(dir("t4"));
}

/// Zero-cost-when-off: an obs-off sweep carries no obs artifacts — no
/// report, no `cpu_families` / `solve_ms` keys in the JSON — and turning
/// obs ON changes no simulation measurement.
#[test]
fn disabled_obs_is_invisible_and_enabling_it_changes_nothing() {
    let g = SweepGrid {
        families: vec![ClusterFamily::Amdahl],
        nodes: vec![5],
        cores: vec![2],
        write_paths: vec![WritePath::DirectIo],
        lzo: vec![false],
        workloads: vec![Workload::DfsioWrite, Workload::Search],
        ..SweepGrid::paper_default(7, 1, 1)
    };
    let base = SweepOptions {
        threads: 2,
        dfsio_bytes_per_worker: 32.0 * MIB,
        dfsio_workers: 2,
        ..SweepOptions::default()
    };
    let off = run_sweep(&g, &base);
    let json = off.to_json();
    assert!(!json.contains("cpu_families"), "obs-off JSON grew an obs key");
    assert!(!json.contains("solve_ms"), "wall clock leaked into default JSON");

    let on = run_sweep(&g, &SweepOptions { obs: ObsSpec::full(5.0), ..base });
    for (a, b) in off.records.iter().zip(on.records.iter()) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.seconds, b.seconds, "{}: obs changed simulated time", a.id);
        assert_eq!(a.bytes_moved, b.bytes_moved);
        assert_eq!(a.joules, b.joules, "{}: obs changed the energy model", a.id);
        assert!(!b.cpu_families.is_empty(), "{}: obs-on record lost attribution", b.id);
    }
}

/// `--perf-wallclock` puts `solve_ms` into the perf section (and only
/// there — `sim_json` has no perf section at all).
#[test]
fn perf_wallclock_flag_gates_solve_ms() {
    let g = SweepGrid {
        families: vec![ClusterFamily::Amdahl],
        nodes: vec![5],
        cores: vec![1],
        write_paths: vec![WritePath::DirectIo],
        lzo: vec![false],
        workloads: vec![Workload::DfsioWrite],
        ..SweepGrid::paper_default(7, 1, 1)
    };
    let opts = SweepOptions {
        threads: 1,
        dfsio_bytes_per_worker: 32.0 * MIB,
        dfsio_workers: 2,
        perf_wallclock: true,
        ..SweepOptions::default()
    };
    let r = run_sweep(&g, &opts);
    assert!(r.to_json().contains("\"solve_ms\""), "perf_wallclock did not emit solve_ms");
    assert!(!r.sim_json().contains("solve_ms"));
    assert!(
        r.records.iter().any(|x| x.stats.solve_ns > 0),
        "solver spent no measurable wall time"
    );
}

/// The trace export is a loadable Chrome trace document with the spans
/// the tentpole promises: job phases, map/reduce attempts, block
/// pipelines, shuffle fetches.
#[test]
fn search_trace_contains_the_promised_span_families() {
    let (obs, _) = zones_obs(App::Search, SolverMode::Incremental);
    let trace = obs.trace_json.expect("trace armed");
    assert!(trace.starts_with("{\"traceEvents\":[\n"));
    assert!(trace.ends_with("\n]}\n"));
    assert_eq!(trace.matches('{').count(), trace.matches('}').count());
    for needle in [
        "\"cat\":\"job\"",       // job span + phase instants
        "\"cat\":\"mapreduce\"", // map/reduce attempt spans
        "\"cat\":\"hdfs\"",      // block write/read pipeline spans
        "\"cat\":\"shuffle\"",   // reduce-side fetch spans
        "\"ph\":\"C\"",          // utilization counter samples
    ] {
        assert!(trace.contains(needle), "trace missing {needle}");
    }
    let metrics = obs.metrics_json.expect("metrics armed");
    for needle in ["hdfs.block_write_s", "shuffle.fetch_s", "mapreduce.map_attempt_s", "p95"] {
        assert!(metrics.contains(needle), "metrics missing {needle}");
    }
}

/// Critpath-only spec for the attribution tests: structured spans +
/// sampling + metrics, no Chrome trace.
fn critpath_spec() -> ObsSpec {
    ObsSpec { metrics: true, critpath: true, ..Default::default() }
}

/// Run the racked + faulted profile scenario and return its report.
/// Three racks at 4:1 oversubscription, a mid-run decommission and a
/// crash — the nastiest deterministic setting the profiler must stay
/// byte-stable under.
fn racked_faulted_report(solver: SolverMode, solver_threads: usize) -> BottleneckReport {
    let conf = HadoopConf { racks: 3, rack_oversub: 4.0, ..Default::default() };
    let schedule = FaultSchedule {
        events: vec![
            FaultEvent { at: 0.3, node: 3, kind: FaultKind::Decommission },
            FaultEvent { at: 0.5, node: 5, kind: FaultKind::Crash },
        ],
        ..Default::default()
    };
    let sim = SimConfig::new(42)
        .with_solver(solver)
        .with_solver_threads(solver_threads)
        .with_obs(critpath_spec());
    let run = testdfsio::write_test_faulted(ClusterPreset::Amdahl, sim, 2, 32.0 * MIB, &conf, &schedule);
    run.obs.expect("obs armed").bottleneck.expect("critpath armed")
}

/// The tentpole determinism bar for the profiler: the rendered
/// `BottleneckReport` is byte-identical across 1/2/4 solver threads and
/// both solver modes, even on a racked, faulted grid.
#[test]
fn bottleneck_report_is_byte_identical_across_threads_and_modes() {
    let reference = racked_faulted_report(SolverMode::Incremental, 1).to_json();
    assert!(!reference.is_empty());
    for mode in [SolverMode::Incremental, SolverMode::WholeSet] {
        for threads in [1usize, 2, 4] {
            let got = racked_faulted_report(mode, threads).to_json();
            assert_eq!(
                reference, got,
                "BottleneckReport diverged at {mode:?} / {threads} solver threads"
            );
        }
    }
}

/// Known-answer: the paper's seed scenario (stock 2-core Atom blade,
/// direct-I/O dfsio write) is CPU-bound, and the generic balance
/// re-derivation lands on the paper's four-Atom-core estimate (±1).
#[test]
fn seed_scenario_attributes_the_critical_path_to_cpu() {
    let conf = HadoopConf { direct_io_write: true, ..Default::default() };
    let sim = SimConfig::new(42).with_obs(critpath_spec());
    let run = testdfsio::write_test_on(ClusterPreset::Amdahl, sim, 2, 64.0 * MIB, &conf);
    let b = run.obs.expect("obs armed").bottleneck.expect("critpath armed");
    assert_eq!(b.dominant, "cpu", "seed dfsio write must be CPU-bound: {b:?}");
    assert!(
        b.share(0) > 0.5,
        "CPU must own the majority of the critical path (got {:.2})",
        b.share(0)
    );
    assert!(b.makespan_s > 0.0 && b.cores == 2);
    assert!(
        (3..=5).contains(&b.balanced_cores),
        "balance re-derivation must land on the paper's 4 cores +/-1 (got {})",
        b.balanced_cores
    );
}

/// Known-answer: LZO compression piles compute onto the write path, so
/// the CPU attribution only grows.
#[test]
fn lzo_write_is_cpu_dominated() {
    let conf = HadoopConf {
        buffered_output: true,
        direct_io_write: true,
        lzo_output: true,
        ..Default::default()
    };
    let sim = SimConfig::new(42).with_obs(critpath_spec());
    let run = testdfsio::write_test_on(ClusterPreset::Amdahl, sim, 2, 64.0 * MIB, &conf);
    let b = run.obs.expect("obs armed").bottleneck.expect("critpath armed");
    assert_eq!(b.dominant, "cpu", "LZO write must be CPU-bound: {b:?}");
}

/// Known-answer: give the blade cores to spare (8) and the slowest
/// device (a single HDD), and the attribution follows the bottleneck to
/// the disk.
#[test]
fn disk_bound_write_attributes_to_disk() {
    let conf =
        HadoopConf { data_disk: DiskKind::Hdd, direct_io_write: true, ..Default::default() };
    let sim = SimConfig::new(42).with_obs(critpath_spec());
    let run =
        testdfsio::write_test_on(ClusterPreset::AmdahlNCore(8), sim, 2, 64.0 * MIB, &conf);
    let b = run.obs.expect("obs armed").bottleneck.expect("critpath armed");
    assert_eq!(b.dominant, "disk", "8 cores + one HDD must be disk-bound: {b:?}");
    assert!(
        b.class_seconds[1] > b.class_seconds[0],
        "disk must out-own cpu on the critical path: {b:?}"
    );
}

/// A critpath-armed run perturbs nothing: same throughput, makespan and
/// utilization as the plain run (the collector only observes).
#[test]
fn critpath_collection_does_not_perturb_the_simulation() {
    let conf = HadoopConf { direct_io_write: true, ..Default::default() };
    let plain =
        testdfsio::write_test_on(ClusterPreset::Amdahl, SimConfig::new(42), 2, 48.0 * MIB, &conf);
    let sim = SimConfig::new(42).with_obs(critpath_spec());
    let armed = testdfsio::write_test_on(ClusterPreset::Amdahl, sim, 2, 48.0 * MIB, &conf);
    assert_eq!(plain.result.makespan, armed.result.makespan);
    assert_eq!(plain.result.per_node_mbps, armed.result.per_node_mbps);
    assert_eq!(plain.result.utilization, armed.result.utilization);
    assert!(plain.obs.is_none(), "obs-off run must carry no report");
}

/// Completion-latency percentiles ride the metrics registry: the
/// summary is present, ordered (p50 <= p95 <= p99), and counts every
/// worker.
#[test]
fn job_latency_summary_counts_every_worker() {
    let conf = HadoopConf { direct_io_write: true, ..Default::default() };
    let sim = SimConfig::new(42).with_obs(critpath_spec());
    let run = testdfsio::write_test_on(ClusterPreset::Amdahl, sim, 2, 48.0 * MIB, &conf);
    let l = run.obs.expect("obs armed").job_latency.expect("metrics armed");
    // 8 slaves x 2 workers on the Amdahl preset.
    assert_eq!(l.count, 16, "one latency sample per dfsio worker");
    assert!(l.p50_s > 0.0 && l.p50_s <= l.p95_s && l.p95_s <= l.p99_s);
    assert!(l.mean_s > 0.0);
}

/// The decommission drain and the re-join are visible as `"lifecycle"`
/// spans in the trace export (regression: they used to be instants only,
/// invisible to span-graph consumers).
#[test]
fn lifecycle_spans_cover_drain_and_rejoin() {
    let conf = HadoopConf::default();
    let schedule = FaultSchedule {
        events: vec![
            FaultEvent { at: 0.3, node: 3, kind: FaultKind::Decommission },
            // Recommissioned long after the drain finished: the node is
            // administratively dead, so this is a full re-join.
            FaultEvent { at: 900.0, node: 3, kind: FaultKind::Recommission },
        ],
        ..Default::default()
    };
    let sim = SimConfig::new(42).with_obs(ObsSpec::full(5.0));
    let run =
        testdfsio::write_test_faulted(ClusterPreset::Amdahl, sim, 2, 32.0 * MIB, &conf, &schedule);
    let trace = run.obs.expect("obs armed").trace_json.expect("trace armed");
    assert!(trace.contains("\"cat\":\"lifecycle\""), "no lifecycle spans in the trace");
    assert!(trace.contains("drain n3"), "decommission drain span missing");
    assert!(trace.contains("rejoin n3"), "re-join span missing");
}

/// The §4 reproduction: on the Atom-class blade, a dfsio write burns its
/// cycles in the HDFS protocol family, not compute; the search app adds
/// shuffle and compute families on top.
#[test]
fn family_attribution_matches_the_workload_shape() {
    let idx = |name: &str| FAMILIES.iter().position(|f| *f == name).unwrap();
    let d = dfsio_obs(SolverMode::Incremental).cpu_families;
    assert_eq!(d.len(), FAMILIES.len());
    assert!(d[idx("hdfs")].cpu_core_seconds > 0.0, "dfsio write must burn hdfs CPU");
    assert!(d[idx("hdfs")].joules > 0.0);
    assert_eq!(d[idx("shuffle")].cpu_core_seconds, 0.0, "dfsio has no shuffle");
    assert!(
        d[idx("hdfs")].cpu_core_seconds > d[idx("compute")].cpu_core_seconds,
        "dfsio: protocol overhead must dominate compute"
    );

    let (s, _) = zones_obs(App::Search, SolverMode::Incremental);
    let s = s.cpu_families;
    assert!(s[idx("hdfs")].cpu_core_seconds > 0.0);
    assert!(s[idx("shuffle")].cpu_core_seconds > 0.0, "search shuffles its pairs");
    assert!(s[idx("compute")].cpu_core_seconds > 0.0, "search maps/sorts burn compute");
    assert_eq!(s[idx("balance")].cpu_core_seconds, 0.0, "no balancer ran");
}
